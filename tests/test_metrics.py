"""Observability plane: reduce truth, the bounded quantile sketch,
collector thread-safety with an EXACT ledger tie-out, PER_RANK vs
GLOBAL_REDUCE equivalence, the JSONL sink round trip, declarative SLO
guards, and the reset-vs-accrual race regression."""
import json
import threading

import numpy as np
import pytest

from repro.data.synthetic import small_file_dataset
from repro.fanstore.accounting import ClusterAccounting
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.metrics import (DistributionAccumulator, JsonlSink,
                                    MetricsCollector, Mode, QuantileSketch,
                                    RateAccumulator, Reduce, Ref,
                                    ScalarAccumulator, SloGuard, check_slos,
                                    resolve_path)
from repro.fanstore.prepare import prepare_dataset
from repro.fanstore.spec import ClusterSpec


def _make_files(n=48, seed=3):
    files = small_file_dataset(n, (200, 1_500), num_dirs=3, seed=seed)
    blobs, _ = prepare_dataset(files, 8, compress=False)
    return files, blobs


# ---------------------------------------------------------------------------
# reduce truth on known sequences
# ---------------------------------------------------------------------------

def test_reduce_truth_on_known_sequence():
    c = MetricsCollector()
    for reduce in (Reduce.SUM, Reduce.MEAN, Reduce.MAX, Reduce.MIN,
                   Reduce.COUNT):
        for v in (3.0, 1.0, 4.0, 1.0, 5.0):
            c.record_metric(f"m.{reduce.value}", v, reduce=reduce)
    m = c.snapshot()["metrics"]
    assert m["m.sum"]["value"] == 14.0
    assert m["m.mean"]["value"] == pytest.approx(2.8)
    assert m["m.max"]["value"] == 5.0
    assert m["m.min"]["value"] == 1.0
    assert m["m.count"]["value"] == 5.0
    # every entry carries the full scalar summary alongside the fold
    assert m["m.sum"]["count"] == 5 and m["m.sum"]["min"] == 1.0


def test_scalar_rejects_quantile_reduce():
    with pytest.raises(ValueError, match="Distribution"):
        ScalarAccumulator(Reduce.P99)


def test_distribution_summary_has_quantiles():
    acc = DistributionAccumulator(Reduce.P50)
    for v in range(100):
        acc.observe(float(v))
    s = acc.summary()
    assert s["count"] == 100 and "p50" in s and "p99" in s
    assert 45.0 <= acc.value() <= 55.0


# ---------------------------------------------------------------------------
# quantile sketch: error bounds, bounded memory, merge
# ---------------------------------------------------------------------------

def test_sketch_error_bounds(rng):
    vals = rng.random(50_000)
    sk = QuantileSketch(capacity=512)
    for v in vals:
        sk.add(float(v))
    # rank error of the estimate stays well inside ~2/capacity
    for q in (0.50, 0.99):
        est = sk.query(q)
        frac = float((vals <= est).mean())
        assert abs(frac - q) <= 0.02, (q, est, frac)


def test_sketch_memory_bounded_independent_of_samples(rng):
    sk = QuantileSketch(capacity=64)
    n = 100_000
    for v in rng.random(n):
        sk.add(float(v))
    assert len(sk) <= 64          # O(capacity), NOT O(n)
    assert sk.count == n          # but no sample's weight is lost
    assert sk.compactions > 0


def test_sketch_merge_matches_single_stream(rng):
    vals = rng.random(20_000)
    a, b = QuantileSketch(256), QuantileSketch(256)
    for v in vals[:10_000]:
        a.add(float(v))
    for v in vals[10_000:]:
        b.add(float(v))
    a.merge(b)
    assert len(a) <= 256 and a.count == 20_000
    for q in (0.50, 0.99):
        frac = float((vals <= a.query(q)).mean())
        assert abs(frac - q) <= 0.04


def test_sketch_rejects_tiny_capacity():
    with pytest.raises(ValueError, match=">= 8"):
        QuantileSketch(capacity=4)


# ---------------------------------------------------------------------------
# rate accumulator (injectable clock)
# ---------------------------------------------------------------------------

def test_rate_accumulator_fake_clock():
    t = [100.0]
    acc = RateAccumulator(clock=lambda: t[0])
    acc.observe(10.0)
    acc.observe(30.0)
    t[0] = 104.0
    assert acc.value() == pytest.approx(10.0)    # 40 over 4s
    assert acc.summary()["elapsed_s"] == pytest.approx(4.0)


def test_rate_merge_takes_earliest_birth():
    t = [100.0]
    clock = lambda: t[0]  # noqa: E731
    early = RateAccumulator(clock=clock)
    early.observe(4.0)
    t[0] = 102.0
    late = RateAccumulator(clock=clock)
    late.observe(4.0)
    late.merge(early)
    t[0] = 104.0
    assert late.value() == pytest.approx(8.0 / 4.0)


def test_rate_requires_sum_reduce():
    with pytest.raises(ValueError, match="SUM"):
        RateAccumulator(Reduce.MEAN)


def test_collector_rate_series():
    t = [0.0]
    c = MetricsCollector(clock=lambda: t[0])
    c.record_metric("io.bytes", 100.0, rate=True)
    c.record_metric("io.bytes", 300.0, rate=True)
    t[0] = 2.0
    e = c.snapshot()["metrics"]["io.bytes"]
    assert e["kind"] == "rate" and e["value"] == pytest.approx(200.0)


# ---------------------------------------------------------------------------
# collector: declarations, modes, blocks, versioning
# ---------------------------------------------------------------------------

def test_declaration_conflict_raises():
    c = MetricsCollector()
    c.record_metric("x", 1.0, reduce=Reduce.SUM)
    with pytest.raises(ValueError, match="already declared"):
        c.record_metric("x", 1.0, reduce=Reduce.MEAN)
    with pytest.raises(ValueError, match="already declared"):
        c.record_metric("x", 1.0, reduce=Reduce.SUM, rate=True)


def test_per_rank_vs_global_reduce_equivalence():
    c = MetricsCollector()
    obs = {(0, 0): [1.0, 2.0], (0, 1): [10.0], (1, 0): [5.0, 7.0, 9.0]}
    reduces = [Reduce.SUM, Reduce.MEAN, Reduce.MAX, Reduce.MIN,
               Reduce.COUNT, Reduce.P99]
    for reduce in reduces:
        for rank, vals in obs.items():
            for v in vals:
                c.record_metric(f"m.{reduce.value}", v,
                                reduce=reduce, rank=rank)
    per = c.snapshot(mode=Mode.PER_RANK)["metrics"]
    glob = c.snapshot(mode=Mode.GLOBAL_REDUCE)["metrics"]
    for reduce in reduces:
        name = f"m.{reduce.value}"
        # the two modes are views of the same per-rank store: the
        # folded value is identical, PER_RANK just keeps the keys
        assert per[name]["value"] == glob[name]["value"]
        assert "ranks" in per[name] and "ranks" not in glob[name]
        ranks = per[name]["ranks"]
        assert set(ranks) == {"0/0", "0/1", "1/0"}
        # and the fold is provably the reduction of the rank entries
        rsum = sum(r["sum"] for r in ranks.values())
        rcount = sum(r["count"] for r in ranks.values())
        if reduce is Reduce.SUM:
            assert glob[name]["value"] == rsum
        elif reduce is Reduce.COUNT:
            assert glob[name]["value"] == rcount
        elif reduce is Reduce.MEAN:
            assert glob[name]["value"] == pytest.approx(rsum / rcount)
        elif reduce is Reduce.MAX:
            assert glob[name]["value"] == max(
                r["max"] for r in ranks.values())
        elif reduce is Reduce.MIN:
            assert glob[name]["value"] == min(
                r["min"] for r in ranks.values())


def test_record_block_is_deep_copied_both_ways():
    c = MetricsCollector()
    block = {"rows": [1, 2]}
    c.record_block("bench_block", block)
    block["rows"].append(3)                       # caller mutates after
    snap = c.snapshot()
    assert snap["bench"]["bench_block"] == {"rows": [1, 2]}
    snap["bench"]["bench_block"]["rows"].append(99)   # reader mutates
    assert c.snapshot()["bench"]["bench_block"] == {"rows": [1, 2]}


def test_collector_does_not_keep_cluster_alive():
    """Regression: cluster.metrics must hold its owner weakly — a strong
    back-reference makes a cycle, and an abandoned (never-closed) cluster
    then waits for the cycle GC instead of dying by refcount, stranding
    lazily spawned transport pool threads past test teardown."""
    import weakref
    cluster = FanStoreCluster.from_spec(ClusterSpec(num_nodes=1))
    collector = cluster.metrics
    ref = weakref.ref(cluster)
    cluster.close()
    del cluster
    assert ref() is None
    assert collector.cluster is None
    collector.record_metric("x", 1.0)        # still usable standalone
    assert "faults" not in collector.snapshot()


def test_version_monotonic_across_reset():
    c = MetricsCollector()
    c.record_metric("x", 1.0)
    v1 = c.snapshot()["version"]
    c.reset()
    snap = c.snapshot()
    assert snap["version"] == v1 + 1      # reset never rewinds the stream
    assert snap["metrics"] == {}
    c.record_metric("x", 1.0, reduce=Reduce.MEAN)   # re-declaration OK


# ---------------------------------------------------------------------------
# thread storm: 16 ranks hammer one collector + the transport, then the
# recorded app-level SUM must tie out EXACTLY against the ledger bridge
# ---------------------------------------------------------------------------

def test_thread_storm_exact_ledger_tieout():
    files, blobs = _make_files(n=64, seed=5)
    paths = sorted(files)
    spec = ClusterSpec(num_nodes=2, workers_per_node=8,
                       cache_bytes=1 << 20)
    with FanStoreCluster.from_spec(spec) as cluster:
        cluster.load_partitions(blobs)
        ranks = [(n, w) for n in range(2) for w in range(8)]
        barrier = threading.Barrier(len(ranks))
        errors = []

        def storm(rank):
            try:
                sess = cluster.connect(*rank)
                barrier.wait()
                for rnd in range(3):
                    lo = (rank[0] * 8 + rank[1] + rnd) % 32
                    blobs_out = sess.read_many(paths[lo:lo + 16])
                    sess.record_metric("storm.read_bytes",
                                       sum(len(b) for b in blobs_out))
            except Exception as e:     # pragma: no cover - diagnostic
                errors.append(e)

        threads = [threading.Thread(target=storm, args=(r,)) for r in ranks]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        snap = cluster.metrics.snapshot(mode=Mode.PER_RANK)
        entry = snap["metrics"]["storm.read_bytes"]
        # every byte a session read landed in exactly one ledger bucket
        # (cache hit / local / remote) — so the app-recorded total and
        # the accounting bridge agree EXACTLY, not approximately
        ledger = sum(
            n["modeled"]["cache_hit_bytes"] + n["modeled"]["local_bytes"]
            + n["modeled"]["bytes_in"]
            for n in snap["nodes"].values())
        assert entry["value"] == ledger
        assert entry["count"] == len(ranks) * 3
        # per-rank sums fold back to the global value, all 16 ranks seen
        assert len(entry["ranks"]) == len(ranks)
        assert sum(r["sum"] for r in entry["ranks"].values()) \
            == entry["value"]
        # at quiesce the snapshot equals the live clocks field for field
        for i, nd in snap["nodes"].items():
            clock = cluster.clocks[i]
            assert nd["modeled"]["bytes_in"] == clock.bytes_in
            assert nd["modeled"]["local_bytes"] == clock.local_bytes
            assert nd["modeled"]["cache_hit_bytes"] == clock.cache_hit_bytes
            assert nd["modeled"]["cache_hits"] == clock.cache_hits
            assert nd["modeled"]["busy_s"] == clock.busy_s


# ---------------------------------------------------------------------------
# regression: reset() / snapshot() racing in-flight accrual
# ---------------------------------------------------------------------------

def test_reset_and_snapshot_race_inflight_accrual():
    """Writers accrue tenant rows the way the transport does (under the
    clock lock) while the main thread snapshots and resets. Every
    snapshot must be internally consistent: the tenant rows bumped in
    the same critical section as the lane totals are never observed
    half-applied, and reset never tears an accrual in two."""
    acct = ClusterAccounting(range(2))
    stop = threading.Event()
    errors = []

    def writer(wid):
        i = 0
        try:
            while not stop.is_set():
                with acct.lock:     # exactly the backend accrual shape
                    acct[wid % 2].attribute_tenant(
                        f"t{wid}", nbytes=100, cost_s=0.001, requests=1)
                i += 1
        except Exception as e:      # pragma: no cover - diagnostic
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(4)]
    for t in threads:
        t.start()
    try:
        for rnd in range(200):
            snap = acct.snapshot()["cluster"]
            assert sum(snap["tenant_bytes"].values()) \
                == snap["serve_app_bytes"]
            assert sum(snap["tenant_requests"].values()) \
                == snap["serve_app_requests"]
            if rnd % 20 == 10:
                acct.reset()
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors
    # post-quiesce: reset left live, attributable clocks behind
    acct.reset()
    empty = acct.snapshot()["cluster"]
    assert empty["serve_app_bytes"] == 0 and empty["tenant_bytes"] == {}


# ---------------------------------------------------------------------------
# JSONL sink: round trip, rotation, torn tail, periodic tick
# ---------------------------------------------------------------------------

def test_jsonl_flush_reload_round_trip(tmp_path):
    p = tmp_path / "m.jsonl"
    c = MetricsCollector()
    c.record_metric("a", 1.0)
    with JsonlSink(p) as sink:
        for _ in range(3):
            sink.flush(c)
        assert sink.records_written == 3
    records = JsonlSink.load(p)
    assert [r["version"] for r in records] == [1, 2, 3]
    assert records[-1]["metrics"]["a"]["value"] == 1.0


def test_jsonl_rotation_keeps_every_record(tmp_path):
    p = tmp_path / "m.jsonl"
    c = MetricsCollector()
    with JsonlSink(p, rotate_bytes=150) as sink:
        for _ in range(6):
            sink.flush(c)
        assert sink.rotations >= 1
    assert (tmp_path / "m.jsonl.1").exists()
    records = JsonlSink.load(p)
    assert [r["version"] for r in records] == [1, 2, 3, 4, 5, 6]
    # without the rotated segments only the live tail remains
    assert len(JsonlSink.load(p, include_rotated=False)) < 6


def test_jsonl_torn_tail_dropped_but_midfile_corruption_raises(tmp_path):
    p = tmp_path / "m.jsonl"
    p.write_text('{"version": 1}\n{"version": 2, "to')   # crash mid-append
    assert [r["version"] for r in JsonlSink.load(p)] == [1]
    p.write_text('{"version": 1}\ngarbage\n{"version": 3}\n')
    with pytest.raises(ValueError, match="corrupt"):
        JsonlSink.load(p)


def test_jsonl_tick_is_periodic(tmp_path):
    t = [0.0]
    c = MetricsCollector()
    sink = JsonlSink(tmp_path / "m.jsonl", every_s=5.0, clock=lambda: t[0])
    assert sink.tick(c) is True       # nothing emitted yet -> due
    assert sink.tick(c) is False      # within the window
    t[0] = 4.9
    assert sink.tick(c) is False
    t[0] = 5.0
    assert sink.tick(c) is True
    sink.close()
    assert sink.records_written == 2


# ---------------------------------------------------------------------------
# session-level view
# ---------------------------------------------------------------------------

def test_session_metrics_rank_view():
    files, blobs = _make_files(n=32, seed=7)
    spec = ClusterSpec(num_nodes=2, workers_per_node=2,
                       cache_bytes=1 << 20)
    with FanStoreCluster.from_spec(spec) as cluster:
        cluster.load_partitions(blobs)
        mine = cluster.connect(0, 1)
        other = cluster.connect(1, 0)
        paths = sorted(files)[:8]
        mine.read_many(paths)
        mine.read_many(paths)          # second pass hits the node tier
        mine.record_metric("app.loss", 2.0, reduce=Reduce.MEAN)
        other.record_metric("app.other", 1.0)
        view = mine.metrics()
        assert view["rank"] == "0/1"
        assert view["metrics"]["app.loss"]["value"] == 2.0
        assert "app.other" not in view["metrics"]   # not this rank's
        assert view["node"]["bytes_in"] == cluster.clocks[0].bytes_in
        assert view["cache"]["hits"] == \
            cluster.clocks[0].worker_cache_hits.get(1, 0)
        assert view["cache"]["hits"] > 0


# ---------------------------------------------------------------------------
# declarative SLO guards
# ---------------------------------------------------------------------------

def test_resolve_path_wildcards_and_indices():
    doc = {"arms": {"a": {"v": 1}, "b": {"v": 2}}, "xs": [10, 20, 30]}
    assert dict(resolve_path(doc, "arms.*.v")) == {("a",): 1, ("b",): 2}
    assert resolve_path(doc, "xs.1") == [((), 20)]
    assert [v for _, v in resolve_path(doc, "xs.*")] == [10, 20, 30]
    assert resolve_path(doc, "arms.c.v") == []


def test_resolve_path_dotted_metric_names():
    # metric names contain dots by convention; the longest joined run
    # of segments that names a key wins
    doc = {"metrics": {"train.loss": {"value": 2.0},
                       "train": {"loss": {"value": 99.0}}}}
    # "train.loss" (longest) beats the nested "train" -> "loss" chain
    assert resolve_path(doc, "metrics.train.loss.value") == [((), 2.0)]
    del doc["metrics"]["train.loss"]
    assert resolve_path(doc, "metrics.train.loss.value") == [((), 99.0)]


def test_guard_ref_binds_metric_wildcards():
    doc = {"arms": {"a": {"win": 1.0, "base": 2.0},
                    "b": {"win": 3.0, "base": 2.5}}}
    guards = [SloGuard("overlap_wins", "arms.*.win", "<",
                       Ref("arms.*.base"))]
    violations = check_slos(doc, guards)
    assert len(violations) == 1 and "arms.b.win" in violations[0]
    doc["arms"]["b"]["win"] = 2.0
    assert check_slos(doc, guards) == []


def test_guard_leftover_ref_wildcard_is_for_all():
    # "belady bounds every policy on the same arm": the first ref
    # wildcard consumes the arm binding, the leftover one fans out
    doc = {"sweep": {"zipf": {"belady": 0.9, "lru": 0.7, "fifo": 0.6}}}
    guards = [SloGuard("upper_bound", "sweep.*.belady", ">=",
                       Ref("sweep.*.*"))]
    assert check_slos(doc, guards) == []
    doc["sweep"]["zipf"]["lru"] = 0.95
    assert len(check_slos(doc, guards)) == 1


def test_guard_when_gates_and_missing_paths_fail_loud():
    guards = [SloGuard("speedup", "wire.speedup", ">", 1.0,
                       when=("wire.cpus", ">", 1))]
    assert check_slos({"wire": {"speedup": 0.5, "cpus": 1}}, guards) == []
    assert len(check_slos({"wire": {"speedup": 0.5, "cpus": 4}},
                          guards)) == 1
    # a missing when-path or metric path is a violation, never a skip
    assert any("when-path" in v
               for v in check_slos({"wire": {"speedup": 2.0}}, guards))
    assert any("no value" in v for v in check_slos(
        {"wire": {"cpus": 4}}, guards))


def test_guard_container_and_membership_ops():
    doc = {"stripes": [0, 1, 2], "single": [0], "failed": [3],
           "kill": 3, "ok": True, "shed": 0}
    assert check_slos(doc, [
        SloGuard("striped", "stripes", "min_len", 2),
        SloGuard("one_conn", "single", "subset", (0,)),
        SloGuard("detected", "kill", "in", Ref("failed")),
        SloGuard("attrib", "ok", "truthy"),
        SloGuard("nonempty", "stripes", "nonempty"),
        SloGuard("no_shed", "shed", "==", 0),
    ]) == []
    assert len(check_slos(doc, [
        SloGuard("one_conn", "stripes", "subset", (0,))])) == 1


def test_guard_uncomparable_is_a_violation_not_a_crash():
    doc = {"x": "not-a-number"}
    violations = check_slos(doc, [SloGuard("typed", "x", ">", 1.0)])
    assert len(violations) == 1 and "uncomparable" in violations[0]
