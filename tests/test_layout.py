"""Partition binary format (paper Table 3): pack / iterate / read."""
import struct

import numpy as np
import pytest

try:                                       # property tests need hypothesis;
    from hypothesis import given, settings, strategies as st
except ImportError:                        # a bare interpreter runs the
    given = settings = st = None           # deterministic fallbacks below

from repro.fanstore.layout import (NAME_LEN, STAT_LEN, iter_partition,
                                   load_partition, pack_partition)
from repro.fanstore.metadata import StatRecord


def test_table3_offsets(rng):
    files = [("a/b.bin", b"hello world")]
    blob = pack_partition(files)
    (num,) = struct.unpack_from("<I", blob, 0)
    assert num == 1
    name = blob[4:4 + NAME_LEN].rstrip(b"\0").decode()
    assert name == "a/b.bin"
    st_ = StatRecord.unpack(blob[4 + NAME_LEN: 4 + NAME_LEN + STAT_LEN])
    assert st_.st_size == 11
    (csize,) = struct.unpack_from("<Q", blob, 4 + NAME_LEN + STAT_LEN)
    assert csize == 0                     # uncompressed
    off = 4 + NAME_LEN + STAT_LEN + 8
    assert blob[off: off + 11] == b"hello world"


def test_roundtrip_multi(rng):
    files = [(f"d{i % 3}/f{i}.bin",
              bytes(rng.integers(0, 8, int(rng.integers(0, 3000)),
                                 dtype=np.uint8)))
             for i in range(50)]
    blob = pack_partition(files, compress=True)
    part = load_partition(blob)
    assert part.num_files == 50
    for rec, (path, data) in zip(part.records, files):
        assert rec.path == path
        assert rec.stat.st_size == len(data)
        assert part.read_file(rec) == data


def test_adaptive_compression(rng):
    compressible = bytes(rng.integers(0, 2, 4000, dtype=np.uint8))
    incompressible = bytes(rng.integers(0, 256, 4000, dtype=np.uint8))
    blob = pack_partition([("c.bin", compressible), ("i.bin", incompressible)],
                          compress=True)
    recs = list(iter_partition(blob))
    assert recs[0].compressed_size > 0          # stored compressed
    assert recs[1].compressed_size == 0         # stored raw (paper semantics)
    part = load_partition(blob)
    assert part.read_file(recs[0]) == compressible
    assert part.read_file(recs[1]) == incompressible


def test_long_path_rejected():
    with pytest.raises(ValueError):
        pack_partition([("x" * 300, b"data")])


def test_trailing_bytes_detected():
    blob = pack_partition([("a.bin", b"12345")]) + b"JUNK"
    with pytest.raises(IOError):
        list(iter_partition(blob))


def _check_roundtrip(items):
    files = [(f"p/f{i}.bin", data) for i, data in items]
    blob = pack_partition(files, compress=True)
    part = load_partition(blob)
    assert [(r.path, part.read_file(r)) for r in part.records] == files


if st is not None:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 10 ** 6), st.binary(max_size=500)),
                    min_size=0, max_size=12, unique_by=lambda t: t[0]))
    def test_roundtrip_property(items):
        _check_roundtrip(items)
else:
    def test_roundtrip_property():
        pytest.importorskip("hypothesis")


def test_roundtrip_deterministic(rng):
    """Fallback corpus for the property test: empty set, empty payloads,
    repetitive (compressible) and random (incompressible) bytes."""
    _check_roundtrip([])
    _check_roundtrip([(0, b"")])
    _check_roundtrip([(0, b""), (1, b"\0" * 500), (2, b"ab" * 250),
                      (3, bytes(rng.integers(0, 256, 500, dtype=np.uint8))),
                      (9, b"x")])


def test_stat_record_roundtrip():
    st_ = StatRecord.for_data(12345).replace(st_mtime=1234.5, st_uid=7)
    packed = st_.pack()
    assert len(packed) == STAT_LEN
    out = StatRecord.unpack(packed)
    assert out.st_size == 12345
    assert out.st_uid == 7
    assert abs(out.st_mtime - 1234.5) < 1e-6
