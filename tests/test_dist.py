"""Sharding rules: batch/activation/param spec selection by divisibility.

Spec construction only — no device mesh is required until a spec is applied,
so these run fast on a single-device interpreter. pipeline_par's numerical
equivalence is covered by test_apps_and_pipeline (subprocess, 4 devices).
"""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.pipeline_par import split_stages
from repro.dist.sharding import ShardingRules, make_rules


@pytest.fixture
def mesh():
    # a 1-device mesh still carries named axes of size 1; for spec-selection
    # tests we need real sizes, so fake them via a 1x1 mesh + explicit rules
    return jax.make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Just enough Mesh surface for spec selection (shape + axis_names)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def _rules(dp=4, tp=2, **kw):
    return ShardingRules(mesh=_FakeMesh({"data": dp, "model": tp}),
                         dp_axes=("data",), **kw)


def test_make_rules_partitions_axes(mesh):
    rules = make_rules(mesh)
    assert rules.dp_axes == ("data",)
    assert rules.tp_axis == "model"
    assert rules.dp_size == 1 and rules.tp_size == 1


def test_batch_spec_divisibility():
    rules = _rules(dp=4)
    assert rules.batch_spec("train", 64, 4096) == P(("data",))
    # batch not divisible -> the sequence dim takes the data axes
    assert rules.batch_spec("prefill", 2, 4096) == P(None, ("data",))
    # decode never seq-shards its (B, 1) tokens
    assert rules.batch_spec("decode", 2, 4096) == P()
    # seq_shard preference flips the order
    seq_rules = dataclasses.replace(rules, seq_shard=True)
    assert seq_rules.batch_spec("prefill", 64, 4096) == P(None, ("data",))


def test_batch_spec_no_dp_axes():
    rules = dataclasses.replace(_rules(), dp_axes=())
    assert rules.batch_spec("train", 64, 4096) == P()


def test_param_spec_shards_one_model_dim():
    rules = _rules(tp=4)
    assert rules._param_spec((1024, 512)) == P(None, "model")
    # odd last dim falls back to an earlier divisible dim
    assert rules._param_spec((1024, 513)) == P("model", None)
    # scanned stacks never shard the layer dim
    assert rules._param_spec((32, 513, 515)) == P(None, None, None)
    assert rules._param_spec((32, 512, 513)) == P(None, "model", None)
    # tp=1 -> fully replicated
    assert _rules(tp=1)._param_spec((1024, 512)) == P(None, None)


def test_params_shardings_tree_alignment(mesh):
    rules = make_rules(mesh)
    shapes = {"embed": jax.ShapeDtypeStruct((128, 64), np.float32),
              "layers": {"w": jax.ShapeDtypeStruct((4, 64, 64), np.float32)}}
    shardings = rules.params_shardings(shapes)
    assert set(shardings) == {"embed", "layers"}
    assert shardings["embed"].mesh == mesh


def test_split_stages_shapes_and_divisibility():
    params = {"w": np.zeros((8, 16, 16))}
    staged = split_stages(params, 4)
    assert staged["w"].shape == (4, 2, 16, 16)
    with pytest.raises(ValueError):
        split_stages({"w": np.zeros((9, 4))}, 4)
