"""Device-resident FanStore fetch: multi-device tests via subprocess.

Tests spawn a child python with XLA_FLAGS forcing 8 host devices so the main
pytest process keeps the default single-device view (dry-run contract).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_fetch_uniform_and_overflow():
    print(run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import DeviceStore, DeviceStoreConfig, tokens_from_payload
        mesh = jax.make_mesh((4,2), ("data","model"))
        S, L, G = 64, 8, 16
        tokens = np.arange(S*L, dtype=np.int32).reshape(S, L)
        rng = np.random.default_rng(0)
        idx = rng.permutation(S)[:G].astype(np.int32)
        st = DeviceStore(mesh, DeviceStoreConfig(num_samples=S, sample_bytes=L*4,
                                                 capacity_factor=4.0))
        with mesh:
            arr = st.place_tokens(tokens)
            b, o = jax.jit(st.fetch)(arr, jax.device_put(idx, st.idx_sharding))
            np.testing.assert_array_equal(
                np.asarray(tokens_from_payload(b, L)), tokens[idx])
            assert not np.asarray(o).any()
        # skew at capacity_factor 2 (cap < g_local): overflow flag must trip
        st2 = DeviceStore(mesh, DeviceStoreConfig(num_samples=S, sample_bytes=L*4,
                                                  capacity_factor=2.0))
        with mesh:
            arr2 = st2.place_tokens(tokens)
            skew = np.zeros(G, dtype=np.int32)
            _, o2 = jax.jit(st2.fetch)(arr2, jax.device_put(skew, st2.idx_sharding))
            assert np.asarray(o2).any()
        print("OK")
    """))


def test_fetch_stratified_zero_waste():
    print(run_in_subprocess("""
        import numpy as np, jax
        from repro.core import DeviceStore, DeviceStoreConfig, tokens_from_payload
        from repro.data.sampler import StratifiedSampler
        mesh = jax.make_mesh((4,2), ("data","model"))
        S, L, G = 128, 8, 32
        tokens = np.arange(S*L, dtype=np.int32).reshape(S, L)
        samp = StratifiedSampler(S, G, num_shards=4, seed=1)
        st = DeviceStore(mesh, DeviceStoreConfig(num_samples=S, sample_bytes=L*4,
                                                 capacity_factor=1.0))
        with mesh:
            arr = st.place_tokens(tokens)
            f = jax.jit(st.fetch)
            for _ in range(samp.steps_per_epoch):
                idx = samp.next_batch()
                b, o = f(arr, jax.device_put(idx, st.idx_sharding))
                np.testing.assert_array_equal(
                    np.asarray(tokens_from_payload(b, L)), tokens[idx])
                assert not np.asarray(o).any()
        print("OK")
    """))


def test_fetch_multi_pod_and_replication():
    print(run_in_subprocess("""
        import numpy as np, jax
        from repro.core import DeviceStore, DeviceStoreConfig, tokens_from_payload
        mesh = jax.make_mesh((2,2,2), ("pod","data","model"))
        S, L, G = 64, 8, 16
        tokens = np.arange(S*L, dtype=np.int32).reshape(S, L)
        rng = np.random.default_rng(3)
        idx = rng.permutation(S)[:G].astype(np.int32)
        for pod_axis in (None, "pod"):   # replicated vs pod-sharded store
            st = DeviceStore(mesh, DeviceStoreConfig(
                num_samples=S, sample_bytes=L*4, pod_axis=pod_axis,
                capacity_factor=4.0))
            with mesh:
                arr = st.place_tokens(tokens)
                b, o = jax.jit(st.fetch)(arr, jax.device_put(idx, st.idx_sharding))
                np.testing.assert_array_equal(
                    np.asarray(tokens_from_payload(b, L)), tokens[idx])
        print("OK")
    """))


def test_fetch_dequant_pipeline():
    """Compressed store: int8 records + scales, dequant after fetch."""
    print(run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import DeviceStore, DeviceStoreConfig
        from repro.core.codec import block_quantize, block_dequantize_host
        from repro.kernels import ops
        mesh = jax.make_mesh((4,2), ("data","model"))
        S, F = 32, 512
        rng = np.random.default_rng(0)
        x = rng.standard_normal((S, F)).astype(np.float32)
        q, scales = block_quantize(x)   # (S,F) int8 + (S,F//256) f16
        payload = np.concatenate(
            [q.view(np.uint8), scales.view(np.uint8),
             np.zeros((S, 4), np.uint8)], axis=1)  # packed record, pad to 8B
        st = DeviceStore(mesh, DeviceStoreConfig(
            num_samples=S, sample_bytes=payload.shape[1], capacity_factor=4.0))
        idx = rng.permutation(S)[:8].astype(np.int32)
        with mesh:
            arr = st.place(payload)
            b, _ = jax.jit(st.fetch)(arr, jax.device_put(idx, st.idx_sharding))
            b = np.asarray(jax.device_get(b))
        qf = b[:, :F].view(np.int8)
        sf = b[:, F:F + F // 256 * 2].view(np.float16)
        out = np.asarray(ops.dequant(jnp.asarray(qf), jnp.asarray(sf),
                                     impl="ref", out_dtype=jnp.float32))
        np.testing.assert_allclose(out, block_dequantize_host(q, scales)[idx],
                                   rtol=1e-3, atol=1e-3)
        print("OK")
    """))


def test_int8_grad_sync_matches_fp32():
    print(run_in_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke
        from repro.models import build_model
        from repro.train.optimizer import OptimizerConfig
        from repro.train.train_step import make_train_step, init_state
        mesh = jax.make_mesh((4,2), ("data","model"))
        cfg = get_smoke("chatglm3-6b").scaled(remat=False)
        model = build_model(cfg)
        ocfg = OptimizerConfig(lr=5e-3, warmup_steps=1, total_steps=40)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (16, 32)).astype(np.int32))}
        with mesh:
            sa = init_state(model, jax.random.key(0), ocfg)
            step_a = jax.jit(make_train_step(model, ocfg))
            si = init_state(model, jax.random.key(0), ocfg, grad_sync="int8")
            step_i = jax.jit(make_train_step(model, ocfg, mesh=mesh,
                                             dp_axes=("data",),
                                             grad_sync="int8"))
            for _ in range(6):
                sa, ma = step_a(sa, batch)
                si, mi = step_i(si, batch)
        la, li = float(ma["loss"]), float(mi["loss"])
        assert li < 4.6 and abs(la - li) < 0.2, (la, li)
        print("OK", la, li)
    """))
