"""Wire robustness: the framing/codec layer under hostile conditions.

The socket backend's correctness rests on the wire module's invariants —
frames survive torn (partial) reads, oversized frames are rejected before
allocation, codec flags round-trip per payload, and striped transfers
reassemble exactly once each in order. These tests exercise the layer
directly (socketpairs, crafted frames) so a framing bug fails here with a
protocol-level message, not as a hung cluster test.
"""
import hashlib
import socket
import threading

import pytest

from repro.fanstore import wire


def _pair():
    a, b = socket.socketpair()
    a.settimeout(5)
    b.settimeout(5)
    return a, b


# ---- torn / partial reads ---------------------------------------------------
def test_recv_exact_survives_torn_writes():
    """A frame dribbled across many tiny sends must reassemble intact."""
    a, b = _pair()
    try:
        payload = bytes(range(256)) * 64
        blob = wire.frame(wire.MsgType.DATA,
                          wire.encode_data([payload], serve_ns=7))

        def dribble():
            for i in range(0, len(blob), 37):        # deliberately unaligned
                a.sendall(blob[i:i + 37])

        t = threading.Thread(target=dribble)
        t.start()
        mtype, rbody = wire.read_frame(b)
        t.join()
        assert mtype == wire.MsgType.DATA
        out, serve_ns = wire.decode_data(rbody)
        assert bytes(out[0]) == payload and serve_ns == 7
    finally:
        a.close()
        b.close()


def test_read_frame_errors_on_truncated_stream():
    """A peer dying mid-frame must raise, never hang or hand back short
    bytes as a valid frame."""
    a, b = _pair()
    try:
        blob = wire.frame(wire.MsgType.DATA, wire.encode_data([b"x" * 1000]))
        a.sendall(blob[:len(blob) // 2])
        a.close()                                    # connection torn
        with pytest.raises(ConnectionError):
            wire.read_frame(b)
    finally:
        b.close()


def test_read_frame_reuses_buffer():
    """The reusable receive buffer grows geometrically and yields correct
    bytes across frames of different sizes (no stale-tail bleed)."""
    a, b = _pair()
    try:
        buf = bytearray(8)
        for payload in (b"A" * 5000, b"B" * 10, b"C" * 20000, b""):
            a.sendall(wire.frame(wire.MsgType.DATA,
                                 wire.encode_data([payload])))
            _, body = wire.read_frame(b, buf)
            out, _ = wire.decode_data(body)
            assert bytes(out[0]) == payload
    finally:
        a.close()
        b.close()


# ---- oversized frames -------------------------------------------------------
def test_oversized_frame_rejected_before_allocation():
    """A crafted header advertising > MAX_FRAME_BYTES must be rejected on
    the header alone — the body is never read (or allocated)."""
    a, b = _pair()
    try:
        a.sendall(wire._HEADER.pack(int(wire.MsgType.DATA),
                                    wire.MAX_FRAME_BYTES + 1))
        with pytest.raises(wire.WireError, match="exceeds"):
            wire.read_frame(b)
    finally:
        a.close()
        b.close()


def test_unknown_frame_type_rejected():
    a, b = _pair()
    try:
        a.sendall(wire._HEADER.pack(99, 0))
        with pytest.raises(wire.WireError, match="unknown frame type"):
            wire.read_frame(b)
    finally:
        a.close()
        b.close()


class _FakeSized(bytes):
    """A bytes stand-in lying about its length so the oversize guard can
    be probed without allocating gigabytes."""
    def __new__(cls, fake_len):
        self = super().__new__(cls, b"")
        self._fake_len = fake_len
        return self

    def __len__(self):
        return self._fake_len


def test_send_side_refuses_oversized_body():
    a, b = _pair()
    try:
        big = _FakeSized(wire.MAX_FRAME_BYTES + 1)
        with pytest.raises(wire.WireError, match="exceeds"):
            wire.frame(wire.MsgType.DATA, big)
        with pytest.raises(wire.WireError, match="exceeds"):
            wire.write_frame(a, wire.MsgType.DATA, big)
        with pytest.raises(wire.WireError, match="exceeds"):
            wire.write_frame_parts(a, wire.MsgType.DATA, [big])
    finally:
        a.close()
        b.close()


# ---- codec flags ------------------------------------------------------------
_EAGER = dict(codec="lzss", wire_Bps=1e3, compress_Bps=1e12,
              decompress_Bps=1e12, min_bytes=1)


def test_codec_flags_roundtrip_compressible():
    policy = wire.WireCodecPolicy(**_EAGER)
    payloads = [b"Z" * 4096]                       # highly compressible
    body = wire.encode_data(payloads, policy=policy)
    out, _, raw_b, wire_b = wire.decode_data_ex(body)
    assert bytes(out[0]) == payloads[0]
    assert wire_b < raw_b                          # it shrank on the wire


def test_codec_flags_roundtrip_incompressible():
    """Incompressible bytes ship raw (flag 0) even when the cost model
    says compress — an attempt that doesn't shrink is discarded."""
    policy = wire.WireCodecPolicy(**_EAGER)
    payload = b"".join(hashlib.sha256(bytes([i])).digest()
                       for i in range(256))       # 8 KiB, match-free
    body = wire.encode_data([payload], policy=policy)
    out, _, raw_b, wire_b = wire.decode_data_ex(body)
    assert bytes(out[0]) == payload
    assert wire_b == raw_b                         # no shrink: shipped raw


def test_codec_flags_roundtrip_empty_and_mixed():
    policy = wire.WireCodecPolicy(**_EAGER)
    rand = bytes((i * 7919) % 256 for i in range(4000))
    payloads = [b"", b"Y" * 5000, rand, b"x"]
    body = wire.encode_data(payloads, serve_ns=99, policy=policy)
    out, serve_ns = wire.decode_data(body)
    assert [bytes(p) for p in out] == payloads and serve_ns == 99
    # PUT entries carry the same per-entry flags
    writer, entries = wire.decode_put(wire.encode_put(
        3, [("out/a.bin", b"Q" * 6000), ("out/b.bin", rand)],
        policy=policy))
    assert writer == 3
    assert [(p, bytes(d)) for p, d in entries] == [
        ("out/a.bin", b"Q" * 6000), ("out/b.bin", rand)]


def test_codec_policy_rejects_unknown():
    with pytest.raises(ValueError, match="wire codec"):
        wire.WireCodecPolicy(codec="zstd")


def test_codec_cost_model_direction():
    """The cost model's sign is what matters: a fast wire never engages
    (pure-Python LZSS loses to loopback); a slow wire engages above
    min_bytes; tiny payloads never engage; codec "none" never engages."""
    fast = wire.WireCodecPolicy(codec="lzss")      # honest defaults
    assert not fast.should_compress(1 << 20)
    slow = wire.WireCodecPolicy(codec="lzss", wire_Bps=1e6,
                                compress_Bps=1e9, decompress_Bps=1e9,
                                min_bytes=1024)
    assert slow.should_compress(1 << 20)
    assert not slow.should_compress(512)           # below min_bytes
    assert not wire.WireCodecPolicy().should_compress(1 << 30)


# ---- striping ---------------------------------------------------------------
def _items(sizes):
    return [wire.FetchItem(path=f"f{i}", size=s, stored=s)
            for i, s in enumerate(sizes)]


def test_split_stripes_covers_in_order():
    items = _items([10, 200, 30, 4000, 50, 600, 7, 80])
    bounds = wire.split_stripes(items, 3)
    # contiguous, ordered, complete cover, no empty stripes
    assert bounds[0][0] == 0 and bounds[-1][1] == len(items)
    for (_s0, end), (start, _e1) in zip(bounds, bounds[1:]):
        assert end == start
    assert all(start < end for start, end in bounds)


def test_split_stripes_degenerate_cases():
    items = _items([100])
    assert wire.split_stripes(items, 8) == [(0, 1)]   # never empty stripes
    assert wire.split_stripes(items, 1) == [(0, 1)]
    many = _items([100] * 10)
    bounds = wire.split_stripes(many, 4)
    assert bounds[0][0] == 0 and bounds[-1][1] == 10
    assert len(bounds) <= 4


def test_split_stripes_balances_bytes():
    """One huge item must not drag its stripe into swallowing the rest:
    every stripe carries work."""
    items = _items([1 << 20] + [100] * 9)
    bounds = wire.split_stripes(items, 2)
    assert len(bounds) == 2
    assert bounds[0] == (0, 1)                     # the elephant alone
    assert bounds[1] == (1, 10)


def test_reassemble_out_of_order_stripes():
    """Stripe legs complete in arbitrary order; reassembly restores item
    order exactly."""
    payloads = [bytes([i]) * (10 + i) for i in range(7)]
    bounds = wire.split_stripes(_items([len(p) for p in payloads]), 3)
    chunks = [((start, end), payloads[start:end])
              for start, end in reversed(bounds)]   # completion order != index
    out = wire.reassemble(len(payloads), chunks)
    assert [bytes(p) for p in out] == payloads


def test_reassemble_rejects_missing_or_short():
    payloads = [b"a", b"bb", b"ccc", b"dddd"]
    with pytest.raises(wire.WireError, match="unfilled"):
        wire.reassemble(4, [((0, 2), payloads[:2])])        # hole at 2..4
    with pytest.raises(wire.WireError, match="payloads"):
        wire.reassemble(4, [((0, 3), payloads[:2]),         # short stripe
                            ((3, 4), payloads[3:])])
