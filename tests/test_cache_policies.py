"""Cache policy seam: Belady (clairvoyant MIN) beats LRU, 2Q resists scan
pollution, and every policy mirrors hits/evictions onto NodeClock alike."""
import numpy as np
import pytest

from repro.fanstore.cache import (BeladyCache, ByteLRUCache, TwoQCache,
                                  make_cache)
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.prefetch import EpochSchedule
from repro.fanstore.prepare import prepare_dataset


def simulate(cache, trace, size=100):
    """Demand-read loop as the cluster drives it: get, then put on miss."""
    for p in trace:
        if cache.get(p) is None:
            cache.put(p, b"x" * size)
    return cache.stats


# ---- policy selection -------------------------------------------------------

def test_make_cache_registry_and_custom():
    assert isinstance(make_cache("lru", 10), ByteLRUCache)
    assert isinstance(make_cache("belady", 10), BeladyCache)
    assert isinstance(make_cache("2q", 10), TwoQCache)
    assert isinstance(make_cache(ByteLRUCache, 10), ByteLRUCache)
    with pytest.raises(ValueError):
        make_cache("fifo", 10)


def test_cluster_cache_policy_parameter():
    files = {"d/a.bin": b"x" * 100}
    blobs, _ = prepare_dataset(files, 1, compress=False)
    cluster = FanStoreCluster(2, cache_bytes=1000, cache_policy="2q")
    cluster.load_partitions(blobs)
    assert all(isinstance(c, TwoQCache) for c in cluster.caches.values())
    with pytest.raises(ValueError):
        FanStoreCluster(2, cache_bytes=1000, cache_policy="nope")


# ---- Belady vs LRU ----------------------------------------------------------

def test_belady_beats_lru_on_uniform_random_trace():
    """ISSUE 2 acceptance: exact future knowledge strictly beats recency at
    an equal byte budget under the uniform-random access the paper says
    defeats LRU."""
    rng = np.random.default_rng(0)
    paths = [f"f{i}" for i in range(50)]
    trace = [paths[int(i)] for i in rng.integers(0, 50, size=600)]
    budget = 10 * 100                              # 10 of 50 files
    lru = simulate(ByteLRUCache(budget), trace)
    belady = simulate(BeladyCache(budget, future=trace), trace)
    assert belady.hits > lru.hits
    assert belady.hit_rate > lru.hit_rate


def test_belady_matches_benchmark_comparison():
    """The bench_json cache-policy arm asserts the same inequality through
    the full cluster read path (and is what BENCH_io.json reports)."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.io_scaling import cache_policy_comparison
    out = cache_policy_comparison(num_files=48, cache_files=12, accesses=384)
    assert out["belady_hit_rate"] > out["lru_hit_rate"]


def test_belady_evicts_farthest_and_rejects_dead_entries():
    trace = ["a", "b", "c", "a", "b", "a"]
    cache = BeladyCache(200, future=trace)         # holds two 100 B entries
    assert cache.get("a") is None
    cache.put("a", b"x" * 100)
    assert cache.get("b") is None
    cache.put("b", b"x" * 100)
    assert cache.get("c") is None
    # c is never reused: admission refused rather than evicting a or b
    cache.put("c", b"x" * 100)
    assert "c" not in cache and "a" in cache and "b" in cache
    assert cache.stats.rejections == 1
    assert cache.get("a") is not None
    assert cache.get("b") is not None
    assert cache.get("a") is not None
    assert cache.stats.hits == 3


def test_belady_eviction_prefers_farthest_next_use():
    trace = ["a", "b", "c", "b", "c", "a"]         # a is reused farthest out
    cache = BeladyCache(200, future=trace)
    cache.get("a"), cache.put("a", b"x" * 100)
    cache.get("b"), cache.put("b", b"x" * 100)
    cache.get("c"), cache.put("c", b"x" * 100)     # evicts a (farthest), not b
    assert "a" not in cache and "b" in cache and "c" in cache


def test_belady_admits_replacement_of_resident_entry():
    """Regression: upgrading a resident entry (e.g. a size-only placeholder
    refetched by a materializing read) frees its own bytes and must not be
    rejected for being its own farthest-next-use competitor."""
    trace = ["a", "b", "a", "b", "a", "b"]
    cache = BeladyCache(200, future=trace)
    cache.get("a"), cache.put("a", None, size=100)     # placeholders fill
    cache.get("b"), cache.put("b", None, size=100)     # the whole budget
    assert cache.get("a", require_data=True) is None   # modeled -> refetch
    cache.put("a", b"x" * 100)                         # same-size upgrade
    assert cache.stats.rejections == 0
    assert cache.get("a", require_data=True).data == b"x" * 100


def test_schedule_normalizes_paths_to_cache_keys():
    """Regression: slash-prefixed trace paths must still feed the Belady
    oracle with the normalized keys the cluster cache uses."""
    sched = EpochSchedule.from_trace({0: [["/d/a.bin", "d/b.bin"]]})
    assert sched.future_paths(0) == ["d/a.bin", "d/b.bin"]
    cache = BeladyCache(100, future=sched.future_paths(0))
    assert cache._next_use("d/a.bin") == 0


def test_belady_extend_future_across_epochs():
    epoch = ["a", "b", "a"]
    cache = BeladyCache(500, future=epoch)
    cache.extend_future(epoch)
    q = cache._future["a"]
    assert list(q) == [0, 2, 3, 5]


# ---- 2Q scan resistance -----------------------------------------------------

def test_twoq_resists_one_shot_scan_pollution():
    """A hot working set interleaved with a long one-shot scan: LRU lets the
    scan evict the hot files; 2Q keeps them in the protected queue."""
    rng = np.random.default_rng(1)
    hot = [f"hot{i}" for i in range(8)]
    scan = [f"scan{i}" for i in range(300)]
    trace = []
    si = 0
    for _ in range(40):                            # warm the hot set + scan
        trace += [hot[int(i)] for i in rng.integers(0, 8, size=6)]
        trace += scan[si:si + 6]
        si += 6
    budget = 16 * 100                              # 2x the hot set
    lru = simulate(ByteLRUCache(budget), trace)
    twoq = simulate(TwoQCache(budget), trace)
    assert twoq.hit_rate > lru.hit_rate


def test_twoq_promotes_only_reused_files():
    cache = TwoQCache(400, kin=0.25, kout=0.5)
    # one-shot traffic FIFOs through probation; re-referenced files reach
    # the protected main queue via the ghost list
    for i in range(6):
        p = f"s{i}"
        assert cache.get(p) is None
        cache.put(p, b"x" * 100)
    assert cache.used_bytes <= 400
    # s0 FIFO'd out through probation into the ghost list: miss, then the
    # refill is admitted into the protected main queue
    assert cache.get("s0") is None
    cache.put("s0", b"x" * 100)
    assert "s0" not in cache._a1in and "s0" in cache


# ---- NodeClock mirroring ----------------------------------------------------

@pytest.mark.parametrize("policy", ["lru", "belady", "2q", "lfu", "arc",
                                    "gdsf", "predictive"])
def test_policies_mirror_counters_onto_node_clock(policy):
    files = {f"d/f{i}.bin": b"z" * 1000 for i in range(16)}
    blobs, _ = prepare_dataset(files, 1, compress=False)
    cluster = FanStoreCluster(2, cache_bytes=3500, cache_policy=policy)
    cluster.load_partitions(blobs)
    paths = sorted(files)
    if policy == "belady":
        EpochSchedule.from_trace({1: [paths, paths]}).install_futures(cluster)
    cluster.read_many(1, paths)
    cluster.read_many(1, paths)
    cache = cluster.caches[1]
    clock = cluster.clocks[1]
    assert clock.cache_hits == cache.stats.hits
    assert clock.cache_misses == cache.stats.misses
    assert clock.cache_evictions == cache.stats.evictions
    assert clock.cache_hit_bytes == cache.stats.hit_bytes
    assert cache.used_bytes <= 3500
