"""Metadata: stat packing, tables, readdir, placement hashing."""
import numpy as np
import pytest

try:                                       # property tests need hypothesis;
    from hypothesis import given, settings, strategies as st
except ImportError:                        # a bare interpreter runs the
    given = settings = st = None           # deterministic fallbacks below

from repro.fanstore.metadata import (FileLocation, MetadataTable, StatRecord,
                                     modulo_placement, path_hash)
from repro.fanstore.placement import ConsistentHashRing


def _loc(n=0):
    return FileLocation(node_id=n, partition_id=0, record_index=0)


def test_insert_lookup_stat_readdir():
    t = MetadataTable()
    t.insert("train/cls_0/img0.bin", StatRecord.for_data(10), _loc())
    t.insert("train/cls_0/img1.bin", StatRecord.for_data(20), _loc(1))
    t.insert("train/cls_1/img2.bin", StatRecord.for_data(30), _loc())
    t.insert("val/v.bin", StatRecord.for_data(5), _loc())
    assert len(t) == 4
    assert t.stat("train/cls_0/img1.bin").st_size == 20
    assert t.stat("train").is_dir
    assert t.readdir("train") == ["cls_0", "cls_1"]
    assert t.readdir("train/cls_0") == ["img0.bin", "img1.bin"]
    assert t.readdir("") == ["train", "val"]
    assert t.readdir("nope") is None
    assert t.stat("missing.bin") is None


def test_modulo_placement_stable():
    assert modulo_placement("out/x.ckpt", 16) == modulo_placement("out/x.ckpt", 16)
    # spread across nodes
    owners = {modulo_placement(f"out/f{i}", 16) for i in range(200)}
    assert len(owners) == 16


def test_ring_basic():
    ring = ConsistentHashRing(range(8))
    assert ring.owner("a/b") in range(8)
    assert ring.owners("a/b", 3) == ring.owners("a/b", 3)
    assert len(set(ring.owners("a/b", 3))) == 3


def test_ring_minimal_movement():
    """Consistent hashing's point: removing one node moves only its keys."""
    ring = ConsistentHashRing(range(16))
    keys = [f"part/{i}" for i in range(2000)]
    before = {k: ring.owner(k) for k in keys}
    ring.remove_node(7)
    after = {k: ring.owner(k) for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    # only keys owned by node 7 move
    assert all(before[k] == 7 for k in moved)
    assert all(after[k] != 7 for k in keys)
    # approximately 1/16 of keys lived on node 7
    assert len(moved) < 2000 * 3 / 16


def _check_ring_owner_properties(nodes, key, k):
    ring = ConsistentHashRing(nodes)
    k = min(k, len(nodes))
    owners = ring.owners(key, k)
    assert len(owners) == k == len(set(owners))
    assert all(o in nodes for o in owners)
    assert ring.owner(key) == owners[0]


if st is not None:
    @settings(max_examples=50, deadline=None)
    @given(st.text(min_size=1, max_size=64), st.integers(1, 512))
    def test_modulo_in_range(path, n):
        assert 0 <= modulo_placement(path, n) < n

    @settings(max_examples=30, deadline=None)
    @given(st.sets(st.integers(0, 1000), min_size=2, max_size=40),
           st.text(min_size=1, max_size=32), st.integers(1, 5))
    def test_ring_owner_properties(nodes, key, k):
        _check_ring_owner_properties(nodes, key, k)
else:
    def test_modulo_in_range():
        pytest.importorskip("hypothesis")

    def test_ring_owner_properties():
        pytest.importorskip("hypothesis")


def test_ring_owner_properties_deterministic():
    """Fallback corpus: small/large node sets, unicode keys, k extremes."""
    for path in ("a", "train/cls_0/img0.bin", "ünïcode/päth", "x" * 64):
        for n in (1, 2, 7, 512):
            assert 0 <= modulo_placement(path, n) < n
    _check_ring_owner_properties({0, 1}, "a/b", 2)
    _check_ring_owner_properties(set(range(0, 1000, 37)), "key", 5)
    _check_ring_owner_properties({3, 900}, "ünïcode", 1)
