"""Placement layer: owner policies, ring rebalance minimality, selectors."""
import pytest

from repro.fanstore.metadata import modulo_placement
from repro.fanstore.placement import (ConsistentHashRing, LeastLoadedSelector,
                                      ModuloPlacement, PowerOfTwoSelector,
                                      RingPlacement)


def test_modulo_placement_matches_paper_hash():
    p = ModuloPlacement(16)
    for path in ("out/x.ckpt", "train/cls_0/img0.bin", "a"):
        assert p.owner(path) == modulo_placement(path, 16)
    with pytest.raises(ValueError):
        ModuloPlacement(0)


def test_modulo_replica_set_distinct_and_bounded():
    p = ModuloPlacement(8)
    rs = p.replica_set("out/x.ckpt", 3)
    assert len(rs) == 3 == len(set(rs))
    assert rs[0] == p.owner("out/x.ckpt")
    with pytest.raises(ValueError):
        p.replica_set("out/x.ckpt", 9)


def test_ring_placement_rebalance_minimal_on_remove():
    """Consistent hashing's point: removing one node moves only its keys."""
    p = RingPlacement(range(16))
    keys = [f"part/{i}" for i in range(2000)]
    before = {k: p.owner(k) for k in keys}
    p.remove_node(7)
    after = {k: p.owner(k) for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    assert all(before[k] == 7 for k in moved)       # only node 7's keys move
    assert all(after[k] != 7 for k in keys)
    # approximately 1/16 of keys lived on node 7
    assert len(moved) < 2000 * 3 / 16


def test_ring_placement_rebalance_minimal_on_add():
    """Adding a node steals ~1/(n+1) of the keyspace and nothing else moves
    between surviving nodes (moved keys all land on the new node)."""
    p = RingPlacement(range(16))
    keys = [f"part/{i}" for i in range(2000)]
    before = {k: p.owner(k) for k in keys}
    p.add_node(16)
    after = {k: p.owner(k) for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    assert moved                                    # the new node gets keys
    assert all(after[k] == 16 for k in moved)       # ...and only it
    # approximately 1/17 of the keyspace moves
    assert len(moved) < 2000 * 3 / 17


def test_ring_placement_replica_set():
    p = RingPlacement(range(8))
    owners = p.replica_set("a/b", 3)
    assert len(owners) == 3 == len(set(owners))
    assert owners[0] == p.owner("a/b")


def test_least_loaded_selector():
    s = LeastLoadedSelector()
    load = {0: 5.0, 1: 1.0, 2: 3.0}
    assert s.choose([0, 1, 2], load) == 1
    # ties break deterministically by node id
    assert s.choose([2, 0], {0: 1.0, 2: 1.0}) == 0
    # unknown nodes count as idle
    assert s.choose([0, 9], load) == 9


def test_power_of_two_selector_degenerates_to_least_loaded():
    s = PowerOfTwoSelector(seed=3)
    assert s.choose([0, 1], {0: 5.0, 1: 1.0}) == 1
    assert s.choose([4], {4: 9.0}) == 4


def test_power_of_two_selector_biases_toward_light_nodes():
    s = PowerOfTwoSelector(seed=1)
    owners = list(range(8))
    load = {o: float(o) for o in owners}        # node 0 lightest, 7 heaviest
    picks = [s.choose(owners, load) for _ in range(400)]
    assert set(picks) <= set(owners)
    # the heaviest node is only picked when sampled twice (~1/64 of draws)
    assert picks.count(7) < picks.count(0)
    assert picks.count(7) < 30


def test_ring_used_by_metadata_compat_import():
    """ConsistentHashRing moved to placement; the old import path and the
    package export must keep resolving to the same class."""
    from repro.fanstore import metadata
    assert metadata.ConsistentHashRing is ConsistentHashRing
    import repro.fanstore as fanstore
    assert fanstore.ConsistentHashRing is ConsistentHashRing


def test_load_partitions_by_ring_placement_minimal_remap():
    """ISSUE 2 satellite: with RingPlacement opted in, growing the cluster
    by one node remaps only ~1/N of the partitions (and the moved
    partitions all land on the new node)."""
    from repro.fanstore.cluster import FanStoreCluster
    from repro.fanstore.prepare import prepare_dataset

    files = {f"d/f{i:04d}.bin": bytes([i % 251]) * 64 for i in range(192)}
    blobs, _ = prepare_dataset(files, 96, compress=False)

    def owners(num_nodes):
        cluster = FanStoreCluster(
            num_nodes, placement=RingPlacement(range(num_nodes)))
        cluster.load_partitions(blobs, by_placement=True)
        out = {}
        for path in cluster.metadata.paths():
            _, loc = cluster.metadata.lookup(path)
            out[path] = loc.node_id
        # reads still work through the ring-placed partitions
        assert cluster.read(0, sorted(files)[0]) == files[sorted(files)[0]]
        return out

    before = owners(8)
    after = owners(9)
    moved = [p for p in before if before[p] != after[p]]
    assert moved                                     # the new node got data
    assert all(after[p] == 8 for p in moved)         # ...and only it
    # ~1/9 of the keyspace moves (generous 3x bound, like the ring tests)
    assert len(moved) < len(before) * 3 / 9


def test_load_partitions_by_placement_respects_replication():
    from repro.fanstore.cluster import FanStoreCluster
    from repro.fanstore.prepare import prepare_dataset

    files = {f"d/f{i:04d}.bin": b"z" * 64 for i in range(32)}
    blobs, _ = prepare_dataset(files, 16, compress=False)
    cluster = FanStoreCluster(6, placement=RingPlacement(range(6)))
    cluster.load_partitions(blobs, replication=2, by_placement=True)
    for path in cluster.metadata.paths():
        _, loc = cluster.metadata.lookup(path)
        assert len(loc.all_owners) == 2
        assert loc.node_id == cluster.placement.replica_set(
            f"partition:{loc.partition_id:08d}", 2)[0]
