"""Paper applications (ResNet/SRGAN/FRNN minis) + pipeline parallelism."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.apps import FRNNMini, ResNetMini, SRGANMini

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sgd(params, grads, lr):
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)


def test_resnet_mini_trains(rng):
    model = ResNetMini(num_classes=4, width=8, n_blocks=2)
    params = model.init(jax.random.key(0))
    x = jnp.asarray(rng.standard_normal((8, 16, 16, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 4, 8), jnp.int32)
    batch = {"image": x, "label": y}
    loss_g = jax.jit(jax.value_and_grad(model.loss))
    l0, g = loss_g(params, batch)
    for _ in range(10):
        l, g = loss_g(params, batch)
        params = _sgd(params, g, 0.1)
    assert np.isfinite(float(l)) and float(l) < float(l0)


def test_srgan_mini_two_stages(rng):
    model = SRGANMini(width=8, n_blocks=1)
    params = model.init(jax.random.key(0))
    lr_img = jnp.asarray(rng.standard_normal((2, 8, 8, 3)) * 0.1, jnp.float32)
    hr_img = jnp.asarray(rng.standard_normal((2, 32, 32, 3)) * 0.1, jnp.float32)
    batch = {"lr": lr_img, "hr": hr_img}
    sr = model.generate(params["gen"], lr_img)
    assert sr.shape == (2, 32, 32, 3)                 # 4x upscale
    # stage 1: pixel loss decreases
    lg = jax.jit(jax.value_and_grad(model.init_stage_loss))
    l0, g = lg(params, batch)
    for _ in range(8):
        l, g = lg(params, batch)
        params = _sgd(params, g, 0.05)
    assert float(l) < float(l0)
    # stage 2: both losses finite and g updates don't explode
    gl, dl = model.train_stage_losses(params, batch)
    assert np.isfinite(float(gl)) and np.isfinite(float(dl))


def test_frnn_mini_learns_disruptions(rng):
    model = FRNNMini(n_signals=6, hidden=16, layers=2)
    params = model.init(jax.random.key(1))
    # disrupted shots have a growing oscillation in one channel
    t = np.linspace(0, 1, 24)
    clean = rng.standard_normal((8, 24, 6)) * 0.1
    disrupted = clean.copy()
    disrupted[:, :, 0] += np.sin(40 * t) * t * 3
    x = jnp.asarray(np.concatenate([clean, disrupted]), jnp.float32)
    y = jnp.asarray([0] * 8 + [1] * 8, jnp.int32)
    batch = {"signals": x, "disrupted": y}
    lg = jax.jit(jax.value_and_grad(model.loss))
    l0, _ = lg(params, batch)
    for _ in range(40):
        l, g = lg(params, batch)
        params = _sgd(params, g, 0.2)
    assert float(l) < 0.9 * float(l0)
    logits = model.apply(params, x)
    acc = float(((logits > 0) == (np.asarray(y) > 0)).mean())
    assert acc >= 0.75


@pytest.mark.slow
def test_pipeline_parallel_matches_serial():
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.dist.pipeline_par import pipeline_apply, split_stages
        mesh = jax.make_mesh((4,), ("stage",))
        L, D, B = 8, 16, 8
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.standard_normal((L, D, D)) / np.sqrt(D))
        x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

        def layer_group(w_group, h):      # (L/S, D, D) applied sequentially
            def body(h, w):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, h, w_group)
            return h

        serial = layer_group(Ws, x)
        staged = split_stages({"w": Ws}, 4)
        out = pipeline_apply(lambda p, h: layer_group(p["w"], h),
                             staged, x, mesh=mesh, microbatches=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(serial),
                                   rtol=2e-5, atol=2e-5)
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
