"""LZSS codec: exact roundtrip (unit + property)."""
import numpy as np
import pytest

try:                                       # property tests need hypothesis;
    from hypothesis import given, settings, strategies as st
except ImportError:                        # a bare interpreter runs the
    given = settings = st = None           # deterministic fallbacks below

from repro.fanstore import lzss


def test_empty():
    assert lzss.decompress(lzss.compress(b"")) == b""


def test_single_byte():
    assert lzss.decompress(lzss.compress(b"x")) == b"x"


def test_rle_overlap():
    # overlapping match (classic LZSS self-reference)
    data = b"a" * 1000
    c = lzss.compress(data)
    assert len(c) < 200
    assert lzss.decompress(c) == data


def test_structured(rng):
    base = bytes(rng.integers(0, 4, 64, dtype=np.uint8))
    data = base * 100
    c = lzss.compress(data)
    assert len(c) < len(data) // 2
    assert lzss.decompress(c) == data


def test_incompressible(rng):
    data = bytes(rng.integers(0, 256, 4096, dtype=np.uint8))
    assert lzss.decompress(lzss.compress(data)) == data


def _check_low_entropy(bits, n, seed):
    rng = np.random.default_rng(seed)
    data = bytes(rng.integers(0, 2 ** bits + 1, n, dtype=np.uint8))
    assert lzss.decompress(lzss.compress(data)) == data


if st is not None:
    @settings(max_examples=60, deadline=None)
    @given(st.binary(min_size=0, max_size=2000))
    def test_roundtrip_property(data):
        assert lzss.decompress(lzss.compress(data)) == data

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 7), st.integers(1, 3000), st.integers(0, 2 ** 31 - 1))
    def test_roundtrip_low_entropy(bits, n, seed):
        _check_low_entropy(bits, n, seed)
else:
    def test_roundtrip_property():
        pytest.importorskip("hypothesis")

    def test_roundtrip_low_entropy():
        pytest.importorskip("hypothesis")


def test_roundtrip_deterministic(rng):
    """Fallback corpus: every entropy level x a few lengths, fixed seeds."""
    for data in (b"", b"x", b"ab" * 700, bytes(range(256)) * 4):
        assert lzss.decompress(lzss.compress(data)) == data
    for bits in range(8):
        for n in (1, 37, 3000):
            _check_low_entropy(bits, n, seed=bits * 31 + n)


def test_tuned_encoder_byte_identical_to_reference(rng):
    """The tuned hot loop must emit the reference stream bit for bit —
    same greedy choices, same bounded hash chains, same flag framing."""
    cases = [b"", b"a", b"ab", b"abc", b"aaaa", b"xyzxyz" * 3,
             b"a" * 5000, bytes(range(256)) * 40,
             bytes(rng.integers(0, 2, 4097, dtype=np.uint8)),
             bytes(rng.integers(0, 8, 20000, dtype=np.uint8)),
             bytes(rng.integers(0, 256, 8192, dtype=np.uint8))]
    for data in cases:
        fast = lzss.compress(data)
        ref = lzss.compress_reference(data)
        assert fast == ref
        assert lzss.decompress(fast) == data


def test_tuned_encoder_respects_max_probes(rng):
    data = bytes(rng.integers(0, 4, 6000, dtype=np.uint8))
    for probes in (1, 4, 32):
        assert (lzss.compress(data, max_probes=probes)
                == lzss.compress_reference(data, max_probes=probes))
