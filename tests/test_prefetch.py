"""Clairvoyant prefetch: schedule derivation, window coalescing accounting,
backpressure, loader integration, and the epoch-makespan acceptance pin."""
import threading
import time

import numpy as np
import pytest

from repro.data.pipeline import PrefetchLoader
from repro.data.sampler import GlobalUniformSampler, StratifiedSampler
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.prefetch import EpochSchedule, PrefetchScheduler
from repro.fanstore.prepare import prepare_dataset


def make_cluster(num_nodes, files, *, partitions=4, cache_bytes=1 << 22,
                 cache_policy="lru", **kw):
    blobs, _ = prepare_dataset(files, partitions, compress=False)
    cluster = FanStoreCluster(num_nodes, cache_bytes=cache_bytes,
                              cache_policy=cache_policy, **kw)
    cluster.load_partitions(blobs, replication=1)
    return cluster


def mk_files(n, size=256):
    return {f"d/f{i:04d}.bin": bytes([i % 251]) * size for i in range(n)}


# ---- EpochSchedule ----------------------------------------------------------

def test_peek_epoch_does_not_advance_sampler():
    s = GlobalUniformSampler(64, 8, seed=1)
    s.next_batch()                                   # mid-epoch
    before = (s.state.epoch, s.state.step)
    batches = s.peek_epoch()
    assert (s.state.epoch, s.state.step) == before
    assert len(batches) == s.steps_per_epoch
    # replay equals the live draw for the remaining steps
    for step in range(s.state.step, s.steps_per_epoch):
        assert (batches[step] == s.next_batch()).all()


def test_peek_epoch_works_for_stratified():
    s = StratifiedSampler(128, 32, num_shards=4, seed=2)
    batches = s.peek_epoch()
    seen = np.concatenate(batches)
    assert sorted(seen.tolist()) == list(range(128))


def test_schedule_from_sampler_covers_epoch_and_resolves_owners():
    files = mk_files(64)
    paths = sorted(files)
    cluster = make_cluster(4, files)
    sampler = GlobalUniformSampler(64, 16, seed=0)
    sched = EpochSchedule.from_sampler(sampler, paths, num_requesters=4,
                                       cluster=cluster)
    assert sched.num_steps == sampler.steps_per_epoch
    all_paths = []
    for r in range(4):
        reads = sched.for_requester(r)
        assert len(reads) == 16                      # 64 samples / 4 nodes
        assert all(s.owner >= 0 for s in reads)
        all_paths += [s.path for s in reads]
    assert sorted(all_paths) == paths                # exactly once per epoch
    # steps are ordered and requester slices are contiguous per batch
    steps = [s.step for s in sched.for_requester(1)]
    assert steps == sorted(steps)


def test_schedule_from_trace_and_future_paths():
    sched = EpochSchedule.from_trace({2: [["a", "b"], ["c"], ["a"]]})
    assert sched.future_paths(2) == ["a", "b", "c", "a"]
    assert sched.num_steps == 3
    assert sched.for_requester(7) == []


# ---- window-coalesced accounting -------------------------------------------

def test_prefetch_window_one_round_trip_per_owner_window():
    """K files spanning many batches from one owner = ONE latency, on the
    prefetch lane, with a per-window ledger entry."""
    files = mk_files(16, size=1000)
    cluster = make_cluster(2, files, partitions=1)   # node 0 owns everything
    cluster.reset_clocks()
    staged = cluster.prefetch_window(1, sorted(files))
    assert staged == 16 * 1000
    net = cluster.net
    clock = cluster.clocks[1]
    expect = net.latency_s + 16 * 1000 / net.bandwidth_Bps
    assert abs(clock.prefetch_s - expect) < 1e-12
    assert clock.consume_s == 0.0                    # demand lane untouched
    assert clock.bytes_in == 0
    assert clock.prefetch_bytes == 16 * 1000
    assert clock.prefetch_windows == 1
    assert len(clock.prefetch_log) == 1
    w = clock.prefetch_log[0]
    assert (w.owner, w.files, w.bytes) == (0, 16, 16 * 1000)
    # the owner serves ONE message
    expect_serve = (net.open_overhead_s + 16000 / net.disk_bw_Bps
                    + 16000 / net.bandwidth_Bps)
    assert abs(cluster.clocks[0].serve_s - expect_serve) < 1e-12


def test_prefetched_reads_hit_cache_and_overlap_makespan():
    files = mk_files(32, size=2048)
    cluster = make_cluster(2, files, partitions=1)
    cluster.reset_clocks()
    cluster.prefetch_window(1, sorted(files))
    out = cluster.read_many(1, sorted(files))
    assert out == [files[p] for p in sorted(files)]
    clock = cluster.clocks[1]
    assert clock.cache_hits == 32 and clock.cache_misses == 0
    # demand lane paid only RAM-speed hits; fabric time sits on the
    # prefetch lane; busy_s is the max (modeled overlap), not the sum
    assert clock.consume_s < clock.prefetch_s
    assert clock.busy_s == max(clock.consume_s, clock.serve_s,
                               clock.prefetch_s)


def test_prefetch_window_requires_cache():
    files = mk_files(8)
    cluster = make_cluster(2, files, cache_bytes=0)
    with pytest.raises(ValueError):
        cluster.prefetch_window(0, sorted(files))


def test_prefetch_window_skips_cached_failed_and_output_files():
    files = mk_files(8)
    cluster = make_cluster(3, files, partitions=3)
    cluster.write_file(0, "out/w.bin", b"W" * 64)
    paths = sorted(files)
    cluster.prefetch_window(0, paths + ["out/w.bin"])
    before = cluster.clocks[0].prefetch_bytes
    # second call: everything already cached -> nothing staged
    assert cluster.prefetch_window(0, paths) == 0
    assert cluster.clocks[0].prefetch_bytes == before


# ---- PrefetchScheduler ------------------------------------------------------

def _trace_for(paths, steps, batch):
    return [paths[s * batch:(s + 1) * batch] for s in range(steps)]


def test_scheduler_windows_span_batches():
    files = mk_files(32, size=500)
    cluster = make_cluster(2, files, partitions=1)
    paths = sorted(files)
    sched = EpochSchedule.from_trace({1: _trace_for(paths, 8, 4)}, cluster)
    pf = PrefetchScheduler(cluster, sched, 1, window_steps=4)
    assert pf.num_windows == 2                       # 8 steps / 4 per window
    cluster.reset_clocks()
    pf.ensure(0)                                     # first window only
    pf.drain()
    assert cluster.clocks[1].prefetch_windows == 1
    pf.run_all()
    pf.close()
    # 2 windows x 1 owner = 2 round trips for 8 batches' worth of files
    assert cluster.clocks[1].prefetch_windows == 2
    out = cluster.read_many(1, paths)
    assert out == [files[p] for p in paths]
    assert cluster.clocks[1].cache_hits == 32
    cluster.close()


def test_scheduler_backpressure_byte_cap():
    files = mk_files(64, size=1024)
    cluster = make_cluster(2, files, partitions=1, cache_bytes=1 << 20)
    paths = sorted(files)
    sched = EpochSchedule.from_trace({1: _trace_for(paths, 16, 4)}, cluster)
    # cap below one window's bytes: issuing must still make progress by
    # waiting out the oldest in-flight window
    pf = PrefetchScheduler(cluster, sched, 1, window_steps=2,
                           max_inflight_bytes=4 * 1024)
    issued = pf.run_all()
    pf.close()
    assert issued == pf.num_windows == 8
    assert cluster.clocks[1].prefetch_windows == 8
    assert pf.bytes_scheduled == 64 * 1024
    cluster.close()


def test_scheduler_installs_belady_future():
    files = mk_files(16)
    cluster = make_cluster(2, files, partitions=1, cache_policy="belady")
    paths = sorted(files)
    sched = EpochSchedule.from_trace({1: _trace_for(paths, 4, 4)}, cluster)
    PrefetchScheduler(cluster, sched, 1, window_steps=2)
    assert cluster.caches[1]._future                 # oracle installed


def test_loader_drives_scheduler():
    files = mk_files(64, size=128)
    cluster = make_cluster(4, files)
    paths = sorted(files)
    sampler = GlobalUniformSampler(64, 16, seed=3)
    sched = EpochSchedule.from_sampler(sampler, paths, num_requesters=4,
                                       cluster=cluster)
    pf = PrefetchScheduler(cluster, sched, 0, window_steps=2)
    loader = PrefetchLoader(
        sampler,
        fetch_many=lambda idxs: cluster.read_many(
            0, [paths[i] for i in idxs[:4]]),        # requester 0's slice
        decode=lambda b: b, schedule=pf)
    batches = list(loader.batches(4))
    loader.close()
    assert len(batches) == 4
    clock = cluster.clocks[0]
    assert clock.cache_hits == 16                    # every read prefetched
    assert clock.prefetch_windows >= 2
    cluster.shutdown()


# ---- acceptance pin ---------------------------------------------------------

def test_prefetch_epoch_makespan_beats_batched_at_8_nodes():
    """ISSUE 2 acceptance: with prefetch scheduling enabled the epoch
    makespan is strictly lower than the PR 1 batched arm at >= 8 nodes."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.io_scaling import CPU_NET, run_one
    kw = dict(nodes=8, file_size=65536, count=128, net=CPU_NET,
              reads_per_node=96)
    batched = run_one(batched=True, **kw)
    prefetched = run_one(prefetch=True, window=3, cache_policy="belady", **kw)
    assert prefetched["makespan_s"] < batched["makespan_s"]
    # same payloads crossed the fabric/disk; only the schedule differs
    assert prefetched["bytes_moved"] == batched["bytes_moved"]
