"""Samplers: global view, epoch coverage, stratified balance (property)."""
import numpy as np
import pytest

try:                                       # property tests need hypothesis;
    from hypothesis import given, settings, strategies as st
except ImportError:                        # a bare interpreter runs the
    given = settings = st = None           # deterministic fallbacks below

from repro.data.sampler import (GlobalUniformSampler, PartitionedViewSampler,
                                StratifiedSampler)


def test_uniform_epoch_coverage():
    s = GlobalUniformSampler(128, 16, seed=3)
    seen = np.concatenate([s.next_batch() for _ in range(s.steps_per_epoch)])
    assert sorted(seen.tolist()) == list(range(128))


def test_uniform_reshuffles_across_epochs():
    s = GlobalUniformSampler(64, 64, seed=3)
    e0 = s.next_batch()
    e1 = s.next_batch()
    assert sorted(e0.tolist()) == sorted(e1.tolist())
    assert e0.tolist() != e1.tolist()


def test_stratified_epoch_coverage():
    s = StratifiedSampler(128, 32, num_shards=4, seed=5)
    seen = np.concatenate([s.next_batch() for _ in range(s.steps_per_epoch)])
    assert sorted(seen.tolist()) == list(range(128))


def _check_stratified_balance(d, per_pair, seed):
    """Every requester slice holds exactly per_pair ids from every owner."""
    num_samples = d * d * per_pair * 4
    g = d * d * per_pair
    s = StratifiedSampler(num_samples, g, num_shards=d, seed=seed)
    per_shard = num_samples // d
    for _ in range(3):
        batch = s.next_batch().reshape(d, g // d)
        owners = batch // per_shard
        for r in range(d):
            counts = np.bincount(owners[r], minlength=d)
            assert (counts == per_pair).all()


if st is not None:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 8), st.integers(1, 6), st.integers(1, 4),
           st.integers(0, 99))
    def test_stratified_per_requester_balance(d, per_pair, epochs_unused, seed):
        _check_stratified_balance(d, per_pair, seed)
else:
    def test_stratified_per_requester_balance():
        pytest.importorskip("hypothesis")


def test_stratified_balance_deterministic():
    """Fallback corpus for the property test: corner and midrange shapes."""
    for d, per_pair, seed in ((2, 1, 0), (8, 6, 7), (3, 2, 42), (5, 1, 99)):
        _check_stratified_balance(d, per_pair, seed)


def test_partitioned_view_restricts_workers():
    s = PartitionedViewSampler(100, 20, num_workers=4, seed=0)
    for _ in range(5):
        batch = s.next_batch().reshape(4, 5)
        for w in range(4):
            assert ((batch[w] >= w * 25) & (batch[w] < (w + 1) * 25)).all()


def test_sampler_state_restore():
    a = GlobalUniformSampler(64, 8, seed=9)
    for _ in range(5):
        a.next_batch()
    cursor = type(a.state)(**vars(a.state))
    nxt = a.next_batch()
    b = GlobalUniformSampler(64, 8, seed=9)
    b.restore(cursor)
    assert (b.next_batch() == nxt).all()
