"""Serving layer: generate loop, cache shapes, SWA ring-buffer long decode."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.models import build_model
from repro.serve.kvcache import cache_specs
from repro.serve.serve_step import generate


def test_generate_greedy_deterministic(rng):
    cfg = get_smoke("qwen2-72b").scaled(remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32))}
    out1 = generate(model, params, prompt, steps=6)
    out2 = generate(model, params, prompt, steps=6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 6)


def test_generate_audio_shape(rng):
    cfg = get_smoke("musicgen-large").scaled(remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (2, 8, cfg.num_codebooks)).astype(np.int32))}
    out = generate(model, params, prompt, steps=4)
    assert out.shape == (2, 4, cfg.num_codebooks)
    assert (np.asarray(out) < cfg.vocab_size).all()


def test_swa_ring_buffer_long_decode(rng):
    """Decode far past the window: ring cache must keep exact agreement
    with teacher forcing (window semantics, rope at write time)."""
    cfg = get_smoke("hymba-1.5b").scaled(remat=False, window=8,
                                         global_layers=(0,), num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    T, extra = 12, 10            # decode 10 tokens past a 12-token prompt
    toks = rng.integers(0, cfg.vocab_size, (1, T + extra)).astype(np.int32)
    full = jax.jit(model.logits_full)(params, {"tokens": jnp.asarray(toks)})
    logits, caches = jax.jit(lambda p, b: model.prefill(p, b, T + extra))(
        params, {"tokens": jnp.asarray(toks[:, :T])})
    dec = jax.jit(model.decode_step)
    scale = float(jnp.max(jnp.abs(full.astype(jnp.float32))))
    for s in range(extra):
        nt = jnp.asarray(toks[:, T + s: T + s + 1])
        lg, caches = dec(params, nt, caches, jnp.int32(T + s))
        err = float(jnp.max(jnp.abs(lg.astype(jnp.float32)
                                    - full[:, T + s].astype(jnp.float32))))
        assert err < 0.1 * max(1.0, scale), (s, err)


def test_cache_specs_structure():
    for arch in ("qwen2-72b", "deepseek-v2-236b", "falcon-mamba-7b",
                 "hymba-1.5b"):
        cfg = get_smoke(arch)
        model = build_model(cfg)
        specs = cache_specs(model, batch=2, max_len=64)
        assert len(specs) == len(model.segments)
        for seg, spec in zip(model.segments, specs):
            if seg.kind in ("dense", "moe"):
                assert set(spec) == {"k", "v"}
            elif seg.kind.startswith("mla"):
                assert set(spec) == {"c_kv", "k_rope"}
            elif seg.kind == "mamba":
                assert set(spec) == {"h", "conv"}
            else:
                assert set(spec) == {"k", "v", "h", "conv"}


def test_mla_cache_is_small():
    """MLA latent cache must be far smaller than equivalent full KV."""
    cfg = get_smoke("deepseek-v2-236b")
    model = build_model(cfg)
    specs = cache_specs(model, batch=2, max_len=64)
    mla_bytes = sum(np.prod(v.shape) * 2 for s in specs for v in s.values())
    full_kv_bytes = (cfg.num_layers * 2 * 64 * 2
                     * cfg.num_heads * cfg.v_head_dim * 2)
    assert mla_bytes < 0.5 * full_kv_bytes
