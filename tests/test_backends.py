"""Backend parity: the same traces over modeled / socket / shm wires.

The transport seam's contract: every backend returns byte-identical
payloads, enforces identical visibility semantics (visible-on-close,
single-write), and accrues identical MODELED clocks — only payload
movement (and measured wall accounting) may differ. Plus the seam's
regression pin: ModeledBackend must reproduce the pre-refactor
Transport's accounting exactly (hand-computed from the cost model), and
socket teardown must be deterministic (the conftest leak fixture guards
every test here too).
"""
import dataclasses
import threading

import pytest

from repro.fanstore import wire
from repro.fanstore.api import FanStoreSession
from repro.fanstore.backends import BACKENDS, ShmArena, make_backend
from repro.fanstore.cluster import FanStoreCluster, InterconnectModel
from repro.fanstore.intercept import intercept
from repro.fanstore.prepare import prepare_dataset

ALL_BACKENDS = sorted(BACKENDS)
# the two-sided wires share the base cost model verbatim; rdma's one-sided
# fabric deviates BY CONTRACT (no owner serve lane) and is pinned separately
TWO_SIDED = [b for b in ALL_BACKENDS if b != "rdma"]


def make_files(n=24, compress=True):
    # mixed compressible / incompressible payloads so both the packed and
    # raw partition-record paths cross every wire
    files = {}
    for i in range(n):
        if i % 3 == 0:
            files[f"train/f_{i:03d}.bin"] = bytes([i % 251]) * (2000 + i)
        else:
            files[f"train/f_{i:03d}.bin"] = bytes(
                (i * j * 2654435761) % 256 for j in range(1500 + i))
    return files


@pytest.fixture(scope="module")
def dataset():
    files = make_files()
    blobs, _ = prepare_dataset(files, 8, compress=True)
    return files, blobs


def build(backend, blobs, *, nodes=4, cache_mb=0, policy="lru"):
    c = FanStoreCluster(nodes, backend=backend,
                        cache_bytes=cache_mb * 1024 * 1024,
                        cache_policy=policy)
    c.load_partitions(blobs, replication=1)
    return c


# ---- payload parity ---------------------------------------------------------
@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_read_many_payload_parity(backend, dataset):
    files, blobs = dataset
    paths = sorted(files)
    with build(backend, blobs) as c:
        for requester in range(c.num_nodes):
            got = [bytes(d) for d in c.read_many(requester, paths)]
            assert got == [files[p] for p in paths]


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_prefetch_window_trace_parity(backend, dataset):
    files, blobs = dataset
    paths = sorted(files)
    with build(backend, blobs, cache_mb=8, policy="lru") as c:
        staged = c.prefetch_window(1, paths)
        assert staged > 0
        got = [bytes(d) for d in c.read_many(1, paths)]
        assert got == [files[p] for p in paths]
        # every non-local demand read must have hit the prefetched cache
        assert c.clocks[1].cache_misses == 0
        assert c.clocks[1].cache_hits > 0


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_write_many_and_checkpoint_trace(backend, dataset):
    _, blobs = dataset
    payloads = {f"out/w_{i:02d}.bin": bytes([i]) * (5000 + i)
                for i in range(8)}
    with build(backend, blobs) as c:
        c.write_many(2, sorted(payloads.items()))
        for reader in range(c.num_nodes):
            got = [bytes(d) for d in c.read_many(reader, sorted(payloads))]
            assert got == [payloads[p] for p in sorted(payloads)]
        # streaming checkpoint shards ride the same put verbs
        session = FanStoreSession(c, 1)
        writer = session.checkpoint_writer(chunk_bytes=1024)
        shard = bytes(range(256)) * 40
        writer.write_shard("ckpt/step_1/shard_000.npy", shard)
        assert bytes(c.read(3, "ckpt/step_1/shard_000.npy")) == shard
        assert writer.chunks_flushed >= len(shard) // 1024


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_visibility_and_single_write_semantics(backend, dataset):
    _, blobs = dataset
    with build(backend, blobs) as c:
        s_writer = FanStoreSession(c, 0)
        s_reader = FanStoreSession(c, 3)
        fd = s_writer.open("out/vis.bin", "wb")
        s_writer.write(fd, b"payload")
        s_writer.fsync(fd)                      # streamed, NOT yet visible
        assert not s_reader.exists("out/vis.bin")
        s_writer.close(fd)                      # visible-on-close
        assert s_reader.exists("out/vis.bin")
        assert s_reader.read_many(["out/vis.bin"])[0] == b"payload"
        with pytest.raises(PermissionError):    # single-write
            c.write_file(1, "out/vis.bin", b"other")
        with pytest.raises(PermissionError):    # inputs immutable
            c.write_file(1, c.nodes[1].local_paths()[0], b"x")


def test_modeled_clock_parity_across_backends(dataset):
    """The modeled timelines are backend-independent BY CONTRACT for every
    two-sided wire: the same trace accrues identical NodeClocks whichever
    wire moved the bytes. (rdma's one-sided fabric deviates by design and
    is pinned in test_rdma_one_sided_accounting_pin.)"""
    files, blobs = dataset
    paths = sorted(files)
    snapshots = {}
    for backend in TWO_SIDED:
        with build(backend, blobs) as c:
            for requester in range(c.num_nodes):
                c.read_many(requester, paths[requester::2])
            c.write_many(1, [("out/a.bin", b"A" * 4096)])
            snapshots[backend] = {
                nid: dataclasses.replace(clock, prefetch_log=[])
                for nid, clock in c.clocks.items()}
    base = snapshots["modeled"]
    for backend in TWO_SIDED:
        assert snapshots[backend] == base, (
            f"{backend} modeled clocks drifted from the modeled backend")


# ---- regression pin: modeled accounting == pre-refactor Transport ----------
def test_modeled_accounting_regression_pin(dataset):
    """Hand-computed pre-refactor model, pinned: a batched fetch of K
    remote files from one owner costs the requester ONE latency plus the
    byte time, and the owner one open_overhead plus disk+NIC byte time."""
    files, blobs = dataset
    net = InterconnectModel()
    with FanStoreCluster(2, backend="modeled", interconnect=net) as c:
        c.load_partitions(blobs, replication=1)
        remote = [p for p in sorted(files) if not c.nodes[0].has(p)][:5]
        items = []
        for p in remote:
            st, loc = c.metadata.lookup(p)
            items.append(c._fetch_item(p, st, loc))
        c.read_many(0, remote, batched=True)
        stored = sum(it.stored for it in items)
        expect = net.latency_s + stored / net.bandwidth_Bps
        for it in items:
            if it.compressed:
                expect += it.size / net.decompress_Bps
        assert c.clocks[0].consume_s == pytest.approx(expect, rel=0, abs=0)
        expect_serve = (net.open_overhead_s + stored / net.disk_bw_Bps
                        + stored / net.bandwidth_Bps)
        assert c.clocks[1].serve_s == pytest.approx(expect_serve,
                                                    rel=0, abs=0)
        assert c.clocks[0].bytes_in == stored
        assert c.clocks[1].bytes_out == stored


# ---- measured accounting ----------------------------------------------------
@pytest.mark.parametrize("backend", ["socket", "shm"])
def test_measured_wall_clocks_accrue(backend, dataset):
    files, blobs = dataset
    paths = sorted(files)
    with build(backend, blobs) as c:
        c.read_many(0, paths)
        c.write_many(0, [("out/m.bin", b"M" * 8192)])
        wall = c.accounting.wall
        assert c.measured_makespan_s() > 0
        assert sum(w.consume_ns for w in wall.values()) > 0
        assert sum(w.serve_ns for w in wall.values()) > 0
        remote_bytes = sum(len(files[p]) for p in paths
                           if not c.nodes[0].has(p))
        local_bytes = sum(len(files[p]) for p in paths
                          if c.nodes[0].has(p))
        assert wall[0].bytes_in == remote_bytes + local_bytes
        # reset_clocks clears the measured ledger with the modeled one
        c.reset_clocks()
        assert c.measured_makespan_s() == 0.0


def test_modeled_backend_records_no_wall_time(dataset):
    files, blobs = dataset
    with build("modeled", blobs) as c:
        c.read_many(0, sorted(files))
        assert c.measured_makespan_s() == 0.0
        assert c.accounting.measured_bytes() == 0


# ---- rdma: the one-sided contract -------------------------------------------
def test_rdma_one_sided_accounting_pin(dataset):
    """The one-sided modeled model, hand-pinned: a batched read costs the
    requester ONE registration lookup plus line-rate bytes (+ decompress),
    and the owner's serve lane accrues ZERO — its CPU never ran."""
    files, blobs = dataset
    net = InterconnectModel()
    with FanStoreCluster(2, backend="rdma", interconnect=net) as c:
        c.load_partitions(blobs, replication=1)
        remote = [p for p in sorted(files) if not c.nodes[0].has(p)][:5]
        items = []
        for p in remote:
            st, loc = c.metadata.lookup(p)
            items.append(c._fetch_item(p, st, loc))
        c.read_many(0, remote, batched=True)
        stored = sum(it.stored for it in items)
        expect = net.rdma_lookup_s + stored / net.rdma_bandwidth_Bps
        for it in items:
            if it.compressed:
                expect += it.size / net.decompress_Bps
        assert c.clocks[0].consume_s == pytest.approx(expect, rel=0, abs=0)
        assert c.clocks[1].serve_s == 0.0        # the no-serve-lane contract
        assert c.clocks[0].bytes_in == stored
        assert c.clocks[1].bytes_out == stored   # bytes still left its memory


def test_rdma_measured_zero_serve(dataset):
    """Measured arm: wall time accrues on the requester, NEVER on the
    owner's serve lane (one-sided reads involve no owner CPU)."""
    files, blobs = dataset
    with build("rdma", blobs) as c:
        c.read_many(0, sorted(files))
        c.write_many(0, [("out/r.bin", b"R" * 8192)])
        wall = c.accounting.wall
        assert c.measured_makespan_s() > 0
        assert sum(w.consume_ns for w in wall.values()) > 0
        assert sum(w.serve_ns for w in wall.values()) == 0
        for reader in range(c.num_nodes):
            got = [bytes(d) for d in c.read_many(reader, sorted(files))]
            assert got == [files[p] for p in sorted(files)]


def test_rdma_registration_table_and_rkey(dataset):
    """Registrations are published lazily (one pinned partition segment
    serves every record in it) and a wrong rkey is a protection fault."""
    files, blobs = dataset
    with build("rdma", blobs) as c:
        t = c.transport
        owner = next(i for i in range(4) if c.nodes[i].local_paths())
        paths = c.nodes[owner].local_paths()[:3]
        assert t.registration_table(owner) == {}        # nothing pinned yet
        got = [bytes(d) for d in c.read_many((owner + 1) % 4, paths)]
        assert got == [files[p] for p in paths]
        table = t.registration_table(owner)
        assert set(paths) <= set(table)
        segs = {r.segment for r in table.values()}
        assert len(segs) == 1          # whole-partition pin, shared segment
        region = table[paths[0]]
        with pytest.raises(PermissionError):
            t.read_region(region, region.token ^ 0xDEAD)


def test_rdma_unlink_invalidates_registration(dataset):
    """An unlinked output's registration must be evicted everywhere: a
    rewrite of the freed name re-registers, never serves dead bytes."""
    _, blobs = dataset
    with build("rdma", blobs) as c:
        c.write_many(0, [("out/reg.bin", b"OLD" * 2048)])
        assert bytes(c.read(1, "out/reg.bin")) == b"OLD" * 2048
        owner = c.placement.owner("out/reg.bin")
        assert "out/reg.bin" in c.transport.registration_table(owner)
        c.unlink(2, "out/reg.bin")
        assert "out/reg.bin" not in c.transport.registration_table(owner)
        c.write_many(3, [("out/reg.bin", b"NEW")])
        assert bytes(c.read(1, "out/reg.bin")) == b"NEW"


# ---- socket: striping, pipelining, wire codec --------------------------------
def test_socket_striped_parity_and_attribution(dataset):
    """Striped fetches return byte-identical payloads in order, and the
    measured ledger attributes wall time to every stripe that carried
    bytes (stripe transfers run concurrently, reassembled client-side)."""
    files, blobs = dataset
    paths = sorted(files)
    with FanStoreCluster(4, backend="socket",
                         backend_options={"stripes": 4,
                                          "stripe_min_bytes": 1}) as c:
        c.load_partitions(blobs, replication=1)
        got = [bytes(d) for d in c.read_many(0, paths, batched=True)]
        assert got == [files[p] for p in paths]
        per_stripe = c.accounting.measured_stripe_bytes()
        assert len(per_stripe) > 1, "large batches must fan across stripes"
        assert all(v > 0 for v in per_stripe.values())


def test_socket_single_stripe_unchanged(dataset):
    """stripes=1 keeps the single-connection wire path (the baseline arm
    the benchmark compares against)."""
    files, blobs = dataset
    with FanStoreCluster(4, backend="socket",
                         backend_options={"stripes": 1}) as c:
        c.load_partitions(blobs, replication=1)
        got = [bytes(d) for d in c.read_many(1, sorted(files))]
        assert got == [files[p] for p in sorted(files)]
        assert list(c.accounting.measured_stripe_bytes()) in ([], [0])


def test_socket_wire_codec_engages_by_cost_model(dataset):
    """With a policy whose modeled wire is slow enough, compressible
    payloads ship compressed (wire_sent < wire_raw) and arrive
    byte-identical; incompressible payloads ship raw (flags=0)."""
    files, blobs = dataset
    paths = sorted(files)
    slow_wire = {"wire_codec": "lzss",
                 "wire_policy": {"wire_Bps": 1e6, "compress_Bps": 1e12,
                                 "decompress_Bps": 1e12, "min_bytes": 1}}
    with FanStoreCluster(4, backend="socket",
                         backend_options=slow_wire) as c:
        c.load_partitions(blobs, replication=1)
        got = [bytes(d) for d in c.read_many(0, paths, batched=True)]
        assert got == [files[p] for p in paths]
        saved = c.accounting.measured_wire_saved()
        assert saved > 0, "compressible payloads must shrink on the wire"
    # honest default policy: loopback is far faster than LZSS — never engage
    with FanStoreCluster(4, backend="socket",
                         backend_options={"wire_codec": "lzss"}) as c:
        c.load_partitions(blobs, replication=1)
        c.read_many(0, paths, batched=True)
        assert c.accounting.measured_wire_saved() == 0


def test_socket_striped_teardown_joins_stripe_threads(dataset):
    """Per-stripe connections and the stripe pool are joined
    deterministically at close (covered by the conftest leak fixture)."""
    _, blobs = dataset
    c = FanStoreCluster(4, backend="socket",
                        backend_options={"stripes": 4,
                                         "stripe_min_bytes": 1})
    c.load_partitions(blobs, replication=1)
    c.read_many(0, sorted(c.metadata.paths()), batched=True)
    assert any(t.name.startswith("fanstore-stripe")
               for t in threading.enumerate())
    c.close()
    assert not [t for t in threading.enumerate()
                if t.name.startswith("fanstore")]
    c.close()                                  # idempotent


# ---- commit atomicity under racing writers ---------------------------------
@pytest.mark.parametrize("backend", ["socket", "shm", "rdma"])
def test_racing_writers_single_commit(backend, dataset):
    """Two writers race the same path over a real wire: exactly one
    commit wins, the loser gets PermissionError, and the committed
    payload is exactly the winner's bytes (never an interleaving)."""
    _, blobs = dataset
    for trial in range(5):
        with build(backend, blobs) as c:
            path = f"out/race_{trial}.bin"
            payloads = {1: b"\xaa" * 40000, 2: b"\xbb" * 40000}
            errors = {}
            barrier = threading.Barrier(2)

            def contend(writer):
                try:
                    barrier.wait()
                    c.write_many(writer, [(path, payloads[writer])])
                except PermissionError as e:
                    errors[writer] = e

            ts = [threading.Thread(target=contend, args=(w,))
                  for w in payloads]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            assert len(errors) == 1, "exactly one racer must lose"
            winner = next(w for w in payloads if w not in errors)
            assert bytes(c.read(3, path)) == payloads[winner]
            # the loser's staged chunks were dropped on the owner
            owner = c.placement.owner(path)
            assert not c.nodes[owner]._staging


# ---- the wire protocol itself ----------------------------------------------
def test_wire_frame_roundtrips():
    paths = ["a/b.bin", "c/d e.bin", "träin/ü.bin"]
    body = wire.encode_fetch(paths, materialize=False)
    assert wire.decode_fetch(body) == (paths, False)
    payloads = [b"", b"x" * 10, bytes(range(256))]
    data, serve_ns = wire.decode_data(wire.encode_data(payloads,
                                                       serve_ns=1234))
    assert [bytes(p) for p in data] == payloads and serve_ns == 1234
    writer, entries = wire.decode_put(wire.encode_put(
        7, [("out/x.bin", b"abc"), ("out/y.bin", b"")]))
    assert writer == 7
    assert [(p, bytes(d)) for p, d in entries] == [
        ("out/x.bin", b"abc"), ("out/y.bin", b"")]
    exc = wire.decode_error(wire.encode_error(FileNotFoundError("nope")))
    assert isinstance(exc, FileNotFoundError) and str(exc) == "nope"
    exc = wire.decode_error(wire.encode_error(RuntimeError("boom")))
    assert isinstance(exc, IOError)          # unknown classes degrade


def test_socket_error_frames_reraise(dataset):
    """A server-side FileNotFoundError crosses the wire as an ERR frame
    and re-raises client-side — and the connection stays usable."""
    files, blobs = dataset
    with build("socket", blobs) as c:
        owner = next(i for i in range(4) if i != 1
                     and c.nodes[i].local_paths())
        item = wire.FetchItem(path="no/such.bin", size=10, stored=10)
        with pytest.raises(FileNotFoundError):
            c.transport.fetch_remote_batch(1, owner, [item])
        good = c.nodes[owner].local_paths()[0]
        st, loc = c.metadata.lookup(good)
        out = c.transport.fetch_remote_batch(
            1, owner, [c._fetch_item(good, st, loc)])
        assert bytes(out[0]) == files[good]
        # the STAT verb answers over the same connection
        assert c.transport.stat_remote(1, owner, good).st_size == \
            len(files[good])


def test_socket_teardown_joins_serving_loops(dataset):
    _, blobs = dataset
    c = build("socket", blobs)
    c.read_many(0, sorted(c.metadata.paths())[:6])
    assert any(t.name.startswith("fanstore-serve")
               for t in threading.enumerate())
    c.close()
    assert not [t for t in threading.enumerate()
                if t.name.startswith("fanstore")]
    c.close()                                  # idempotent


# ---- shm extras -------------------------------------------------------------
def test_shm_zero_copy_views(dataset):
    files, blobs = dataset
    with build("shm", blobs) as c:
        owner = next(i for i in range(4) if c.nodes[i].local_paths())
        path = c.nodes[owner].local_paths()[0]
        st, loc = c.metadata.lookup(path)
        views = c.transport.fetch_views(
            1, owner, [c._fetch_item(path, st, loc)])
        assert bytes(views[0]) == files[path]
        rec = c.nodes[owner].record_for(path)
        if not rec.compressed_size:            # raw record: true zero copy
            assert views[0].obj is c.nodes[owner]._partitions[loc.partition_id]


def test_shm_arena_cross_process_handle():
    arena = ShmArena()
    if not arena.available:
        pytest.skip("multiprocessing.shared_memory unavailable")
    payload = bytes(range(256)) * 16
    try:
        name, size = arena.export(payload)
        assert bytes(arena.view(name, size)) == payload
    finally:
        arena.close()
    assert len(arena) == 0


def test_shm_arena_consumer_close_keeps_peer_export():
    """Regression: a consumer arena's close() used to unlink segments it
    had merely attached, destroying the producer's live export."""
    producer, consumer = ShmArena(), ShmArena()
    if not producer.available:
        pytest.skip("multiprocessing.shared_memory unavailable")
    payload = b"peer payload" * 100
    try:
        name, size = producer.export(payload)
        assert bytes(consumer.view(name, size)) == payload
        consumer.close()                   # unmap only — not unlink
        late = ShmArena()
        try:
            assert bytes(late.view(name, size)) == payload
        finally:
            late.close()
    finally:
        producer.close()


# ---- unlink / output GC -----------------------------------------------------
@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_unlink_drops_payload_and_metadata(backend, dataset):
    _, blobs = dataset
    with build(backend, blobs) as c:
        session = FanStoreSession(c, 0)
        session.write_many([("gc/del.bin", b"D" * 4096),
                            ("gc/keep.bin", b"K" * 10)])
        owner = c.placement.owner("gc/del.bin")
        assert c.nodes[owner].has_output("gc/del.bin")
        session.unlink("gc/del.bin")
        assert not c.nodes[owner].has_output("gc/del.bin")   # payload GC'd
        with pytest.raises(FileNotFoundError):
            c.read(1, "gc/del.bin")
        assert session.listdir("gc") == ["keep.bin"]         # delisted
        session.write_many([("gc/del.bin", b"new")])         # name reusable
        assert bytes(c.read(2, "gc/del.bin")) == b"new"
        session.unlink("gc/keep.bin")
        session.unlink("gc/del.bin")
        assert "gc" not in session.listdir("")    # empty dir dissolved
        with pytest.raises(PermissionError):      # inputs immutable
            session.unlink(sorted(c.metadata.paths())[0])
        with pytest.raises(FileNotFoundError):
            session.unlink("gc/never-existed.bin")


@pytest.mark.parametrize("policy", ["lru", "2q"])
def test_unlink_invalidates_client_caches(policy, dataset):
    """Regression: a reader's client cache held the deleted payload, so a
    rewrite of the freed name served the OLD bytes from cache."""
    _, blobs = dataset
    with build("modeled", blobs, cache_mb=4, policy=policy) as c:
        c.write_file(0, "gc/stale.bin", b"OLD PAYLOAD")
        assert bytes(c.read(1, "gc/stale.bin")) == b"OLD PAYLOAD"
        assert "gc/stale.bin" in c.caches[1]          # cached on the reader
        c.unlink(0, "gc/stale.bin")
        assert "gc/stale.bin" not in c.caches[1]
        c.write_file(2, "gc/stale.bin", b"NEW!")
        assert bytes(c.read(1, "gc/stale.bin")) == b"NEW!"


def test_unlink_intercepted_os_calls(dataset):
    import os
    _, blobs = dataset
    with build("modeled", blobs) as c:
        session = FanStoreSession(c, 0)
        with intercept(session):
            with open("/fanstore/gc/a.bin", "wb") as f:
                f.write(b"a")
            with open("/fanstore/gc/b.bin", "wb") as f:
                f.write(b"b")
            assert os.path.exists("/fanstore/gc/a.bin")
            os.unlink("/fanstore/gc/a.bin")
            assert not os.path.exists("/fanstore/gc/a.bin")
            os.remove("/fanstore/gc/b.bin")
            assert not os.path.exists("/fanstore/gc/b.bin")
        assert os.unlink is not None        # detour restored
        with pytest.raises(FileNotFoundError):
            c.read(1, "gc/a.bin")


# ---- lifecycle --------------------------------------------------------------
@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_cluster_context_manager_joins_pool(backend, dataset):
    _, blobs = dataset
    with build(backend, blobs) as c:
        fut = c.read_many_async(0, sorted(c.metadata.paths())[:4])
        assert fut.result()
        assert any(t.name.startswith("fanstore-io")
                   for t in threading.enumerate())
    assert not [t for t in threading.enumerate()
                if t.name.startswith("fanstore")]


def test_closed_backend_refuses_lazy_restart(dataset):
    """Regression: an undrained task racing close() used to respawn the
    serving loops AFTER teardown, leaking them. The lazy path now raises
    on a closed backend; only an explicit start() reopens it."""
    files, blobs = dataset
    c = build("socket", blobs)
    remote = next(p for p in sorted(files) if not c.nodes[0].has(p))
    assert bytes(c.read(0, remote)) == files[remote]
    c.close()
    with pytest.raises(RuntimeError, match="closed"):
        c.read(0, remote)
    assert not [t for t in threading.enumerate()
                if t.name.startswith("fanstore-serve")]
    c.start()                                  # explicit reopen is allowed
    assert bytes(c.read(0, remote)) == files[remote]
    c.close()
    # regression: the lazy pool property used to respawn workers after
    # close() (and the next close() no-op'd, leaking them forever)
    with pytest.raises(RuntimeError, match="closed"):
        c.read_many_async(0, [remote])


def test_make_backend_rejects_unknown():
    with pytest.raises(ValueError, match="unknown transport backend"):
        FanStoreCluster(2, backend="ucx")
