"""Topology-first runtime API: ClusterSpec, per-worker sessions, the
shared node cache tier, multi-requester schedules, and the cross-process
ShmArena attach path."""
import hashlib
import json
import multiprocessing
import threading

import numpy as np
import pytest

from repro.data.synthetic import small_file_dataset
from repro.fanstore.backends.shm import ShmArena, attach_and_digest
from repro.fanstore.cache import NodeCacheTier
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.prefetch import (EpochSchedule, PrefetchScheduler,
                                     SchedulerGroup)
from repro.fanstore.prepare import prepare_dataset
from repro.fanstore.spec import ClusterSpec, WorkerContext


def _make_files(n=48, seed=3):
    files = small_file_dataset(n, (200, 1_500), num_dirs=3, seed=seed)
    blobs, _ = prepare_dataset(files, 8, compress=False)
    return files, blobs


# ---------------------------------------------------------------------------
# ClusterSpec: validation, suggestions, serialization
# ---------------------------------------------------------------------------

def test_spec_unknown_backend_fails_at_construction():
    with pytest.raises(ValueError, match=r"backend.*socket"):
        ClusterSpec(num_nodes=2, backend="sockets")


def test_spec_unknown_cache_policy_fails_at_construction():
    # regression: this used to surface only when the registry was hit,
    # deep inside cluster construction — now the spec names the choices
    with pytest.raises(ValueError, match=r"belady"):
        ClusterSpec(num_nodes=2, cache_policy="baledy")
    with pytest.raises(ValueError, match=r"lru"):
        ClusterSpec(num_nodes=2, cache_policy="nope")


def test_spec_unknown_placement_selector_scope_codec():
    with pytest.raises(ValueError, match=r"ring"):
        ClusterSpec(num_nodes=2, placement="rng")
    with pytest.raises(ValueError, match=r"least-loaded"):
        ClusterSpec(num_nodes=2, selector="least_loaded")
    with pytest.raises(ValueError, match=r"node.*worker|worker.*node"):
        ClusterSpec(num_nodes=2, cache_scope="shared")
    with pytest.raises(ValueError, match=r"lzss"):
        ClusterSpec(num_nodes=2, codec="lzs")


def test_spec_bounds():
    with pytest.raises(ValueError):
        ClusterSpec(num_nodes=0)
    with pytest.raises(ValueError):
        ClusterSpec(num_nodes=2, workers_per_node=0)
    with pytest.raises(ValueError):
        ClusterSpec(num_nodes=2, replication=3)
    with pytest.raises(ValueError):
        ClusterSpec(num_nodes=2, cache_bytes=-1)
    with pytest.raises(ValueError, match=r"interconnect.*latency_s"):
        ClusterSpec(num_nodes=2, interconnect={"latency": 1e-6})


def test_legacy_kwargs_raise_with_suggestions():
    # unknown names must not be silently swallowed; the message suggests
    with pytest.raises(TypeError, match=r"cache_policy"):
        FanStoreCluster(2, cache_polcy="lru")
    with pytest.raises(TypeError, match=r"backend"):
        FanStoreCluster(2, backnd="shm")
    # bad registry VALUES through the legacy path also fail up front
    with pytest.raises(ValueError, match=r"modeled.*shm.*socket|socket"):
        FanStoreCluster(2, backend="tcp")
    with pytest.raises(ValueError, match=r"2q"):
        FanStoreCluster(2, cache_policy="3q", cache_bytes=1024)


def test_spec_json_round_trip_is_identity():
    spec = ClusterSpec(num_nodes=8, workers_per_node=2, backend="shm",
                       cache_policy="belady", cache_bytes=123456,
                       cache_scope="worker", placement="ring",
                       selector="power-of-two", replication=2,
                       io_threads=3,
                       interconnect={"latency_s": 2e-6},
                       backend_options={})
    again = ClusterSpec.from_json(spec.to_json())
    assert again == spec
    assert again.to_json() == spec.to_json()
    # and the dict form rejects unknown fields with suggestions
    d = json.loads(spec.to_json())
    d["num_node"] = 4
    with pytest.raises(ValueError, match=r"num_nodes"):
        ClusterSpec.from_dict(d)


def test_spec_workers_enumeration_and_budget_split():
    spec = ClusterSpec(num_nodes=2, workers_per_node=2, cache_bytes=1000,
                       cache_scope="worker")
    assert [c.key for c in spec.workers()] == [(0, 0), (0, 1),
                                               (1, 0), (1, 1)]
    assert spec.total_workers == 4
    assert spec.worker_cache_bytes() == 500
    assert spec.replace(workers_per_node=1).workers_per_node == 1
    # the tier's private split and the spec helper must agree (one
    # contract, two layers — this pins them together)
    tier = FanStoreCluster.from_spec(spec).cache_tiers[0]
    assert all(c.capacity_bytes == spec.worker_cache_bytes()
               for c in tier.member_caches())


def test_from_spec_equals_legacy_modeled_clocks():
    """Topology/constructor-independence pin: the same trace through a
    spec-built and a legacy-kwargs-built cluster accrues identical
    modeled clocks (single-worker, the pre-topology contract)."""
    files, blobs = _make_files()
    paths = sorted(files)[:24]

    def drive(cluster):
        cluster.load_partitions(blobs, replication=2)
        for nid in range(4):
            cluster.read_many(nid, paths)
        return [(c.consume_s, c.serve_s, c.bytes_in, c.local_bytes)
                for c in cluster.clocks.values()]

    legacy = drive(FanStoreCluster(4, cache_bytes=4096, cache_policy="lru"))
    spec = ClusterSpec(num_nodes=4, cache_bytes=4096, cache_policy="lru")
    via_spec = drive(FanStoreCluster.from_spec(spec))
    assert legacy == via_spec


def test_modeled_costs_worker_independent():
    """Modeled quantities must not depend on WHICH worker read — only
    the attribution breakdown does (by contract, like backends)."""
    files, blobs = _make_files()
    paths = sorted(files)[:16]
    spec = ClusterSpec(num_nodes=2, workers_per_node=2, cache_bytes=1 << 20)

    def drive(worker_id):
        c = FanStoreCluster.from_spec(spec)
        c.load_partitions(blobs)
        c.read_many(0, paths, worker_id=worker_id)
        clock = c.clocks[0]
        return (clock.consume_s, clock.bytes_in, clock.local_bytes,
                clock.cache_hits, clock.cache_misses)

    assert drive(0) == drive(1)


# ---------------------------------------------------------------------------
# connect() / WorkerContext / sessions
# ---------------------------------------------------------------------------

def test_connect_bounds_and_context():
    spec = ClusterSpec(num_nodes=2, workers_per_node=2)
    cluster = FanStoreCluster.from_spec(spec)
    sess = cluster.connect(1, 1)
    assert sess.context == WorkerContext(1, 1)
    assert sess.context.key == (1, 1)
    with pytest.raises(ValueError, match=r"node_id 5"):
        cluster.connect(5)
    with pytest.raises(ValueError, match=r"workers_per_node"):
        cluster.connect(0, worker_id=2)
    # direct session construction rejects the same coordinates (it used
    # to fail late on the first cached read, or silently with no cache)
    from repro.fanstore.api import FanStoreSession
    with pytest.raises(ValueError, match=r"workers_per_node"):
        FanStoreSession(cluster, 0, worker_id=5)
    with pytest.raises(ValueError):
        WorkerContext(-1, 0)


def test_colocated_sessions_share_node_tier():
    """A payload fetched by worker 0 is a RAM hit for worker 1 on the
    same node — the Hoard shared-tier behavior sessions now get."""
    files, blobs = _make_files()
    spec = ClusterSpec(num_nodes=2, workers_per_node=2,
                       cache_bytes=1 << 20)
    cluster = FanStoreCluster.from_spec(spec)
    cluster.load_partitions(blobs)
    s0, s1 = cluster.connect(0, 0), cluster.connect(0, 1)
    paths = sorted(files)[:12]
    assert s0.read_many(paths) == [files[p] for p in paths]
    before = cluster.clocks[0].cache_hits
    assert s1.read_many(paths) == [files[p] for p in paths]
    tier = cluster.cache_tiers[0]
    assert cluster.clocks[0].cache_hits == before + len(paths)
    # attribution: worker 1's hits are credited to worker 1
    assert tier.worker_stats[1].hits == len(paths)
    assert cluster.clocks[0].worker_cache_hits.get(1, 0) == len(paths)
    # worker 0 only warmed (misses), never hit
    assert tier.worker_stats[0].hits == 0


def test_private_scope_does_not_share():
    files, blobs = _make_files()
    spec = ClusterSpec(num_nodes=2, workers_per_node=2,
                       cache_bytes=1 << 20, cache_scope="worker")
    cluster = FanStoreCluster.from_spec(spec)
    cluster.load_partitions(blobs)
    paths = sorted(files)[:12]
    cluster.connect(0, 0).read_many(paths)
    cluster.connect(0, 1).read_many(paths)
    tier = cluster.cache_tiers[0]
    assert tier.worker_stats[0].hits == tier.worker_stats[1].hits == 0
    # each private split holds its own copy; the shared tier would hold one
    caches = tier.member_caches()
    assert len(caches) == 2 and caches[0] is not caches[1]


def test_attribution_sums_match_tier_totals_concurrent():
    """Concurrent co-located sessions: per-worker attribution sums equal
    the tier totals AND the NodeClock mirror — no double-accounting under
    the serving/pool thread interleave (thread-leak fixture guards the
    teardown)."""
    files, blobs = _make_files(n=64)
    spec = ClusterSpec(num_nodes=2, workers_per_node=4,
                       cache_bytes=2 << 20)
    with FanStoreCluster.from_spec(spec) as cluster:
        cluster.load_partitions(blobs)
        paths = sorted(files)
        errs = []

        def worker(w):
            try:
                sess = cluster.connect(0, w)
                rng = np.random.default_rng(w)
                for _ in range(4):
                    chosen = [paths[int(i)] for i in
                              rng.integers(0, len(paths), size=16)]
                    got = sess.read_many(chosen)
                    assert got == [files[p] for p in chosen]
            except BaseException as e:   # surfaces after join
                errs.append(e)

        threads = [threading.Thread(target=worker, args=(w,))
                   for w in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        tier = cluster.cache_tiers[0]
        clock = cluster.clocks[0]
        hits = sum(s.hits for s in tier.worker_stats.values())
        misses = sum(s.misses for s in tier.worker_stats.values())
        assert hits == tier.stats.hits == clock.cache_hits
        assert misses == tier.stats.misses == clock.cache_misses
        assert sum(clock.worker_cache_hits.values()) == clock.cache_hits
        assert sum(clock.worker_cache_misses.values()) == clock.cache_misses
        assert hits + misses == 2 * 4 * 4 * 16 // 2  # 4 workers x 4 x 16


def test_legacy_caches_view_still_works():
    files, blobs = _make_files()
    cluster = FanStoreCluster(2, cache_bytes=1 << 20)
    cluster.load_partitions(blobs)
    paths = sorted(files)[:6]
    cluster.read_many(1, paths)
    assert paths[0] in cluster.caches[1]
    assert cluster.caches[1].used_bytes > 0
    assert isinstance(cluster.cache_tiers[1], NodeCacheTier)


# ---------------------------------------------------------------------------
# Shared tier beats private budgets (the acceptance pin) + benchmarks
# ---------------------------------------------------------------------------

def test_shared_tier_beats_private_at_8x2():
    """8 nodes x 2 workers: the shared node tier strictly beats private
    per-worker caches of the SAME total bytes on both hit rate and
    modeled makespan (deterministic modeled quantities)."""
    from benchmarks.io_scaling import CPU_NET, run_workers_one
    kw = dict(file_size=64 * 1024, count=128, net=CPU_NET,
              reads_per_worker=32, epochs=2)
    shared = run_workers_one(8, 2, shared=True, **kw)
    private = run_workers_one(8, 2, shared=False, **kw)
    assert shared["budget_bytes"] == private["budget_bytes"]
    assert shared["cache_hit_rate"] > private["cache_hit_rate"]
    assert shared["makespan_s"] < private["makespan_s"]
    assert shared["attribution_ok"] and private["attribution_ok"]


def test_workers_comparison_block_shape():
    from benchmarks.io_scaling import workers_comparison
    block = workers_comparison(nodes=4, workers=2, smoke=True)
    assert block["shared_speedup"] > 1.0
    assert block["hit_rate_gain"] > 0
    assert block["shared"]["cache_scope"] == "node"
    assert block["private"]["cache_scope"] == "worker"


# ---------------------------------------------------------------------------
# Per-(node, worker) schedules and the multi-requester driver path
# ---------------------------------------------------------------------------

class _PeekableSampler:
    """Minimal sampler: fixed epoch permutation, peek_epoch only."""

    def __init__(self, n, batch, seed=0):
        self.n, self.batch, self.seed = n, batch, seed

    def peek_epoch(self, epoch=None):
        perm = np.random.default_rng(self.seed).permutation(self.n)
        return [perm[i:i + self.batch]
                for i in range(0, self.n - self.batch + 1, self.batch)]


def test_epoch_schedule_worker_axis_slicing():
    files, blobs = _make_files(n=32)
    paths = sorted(files)
    spec = ClusterSpec(num_nodes=2, workers_per_node=2)
    cluster = FanStoreCluster.from_spec(spec)
    cluster.load_partitions(blobs)
    sampler = _PeekableSampler(32, 8)
    sched = EpochSchedule.from_sampler(sampler, paths, num_requesters=4,
                                       workers_per_node=2, cluster=cluster)
    assert sched.requesters == [(0, 0), (0, 1), (1, 0), (1, 1)]
    # slices are contiguous node-major: requester (n, w) takes slice
    # index n*W + w of each batch (flat comparison built without a
    # cluster — slice indices are not node ids there)
    flat = EpochSchedule.from_sampler(sampler, paths, num_requesters=4)
    for r in range(4):
        key = (r // 2, r % 2)
        assert [s.path for s in sched.for_requester(key)] == \
            [s.path for s in flat.for_requester(r)]
    # node_future merges both workers per step, worker-stable
    merged = sched.node_future(0)
    per_step = len(merged) // sched.num_steps
    w0 = sched.future_paths((0, 0))
    w1 = sched.future_paths((0, 1))
    assert merged[:per_step] == w0[:per_step // 2] + w1[:per_step // 2]
    assert sorted(merged) == sorted(w0 + w1)


def test_scheduler_group_drives_all_workers():
    files, blobs = _make_files(n=64)
    paths = sorted(files)
    spec = ClusterSpec(num_nodes=2, workers_per_node=2,
                       cache_bytes=2 << 20, cache_policy="belady")
    with FanStoreCluster.from_spec(spec) as cluster:
        cluster.load_partitions(blobs)
        traces = {}
        rng = np.random.default_rng(5)
        for n in range(2):
            for w in range(2):
                chosen = [paths[int(i)] for i in rng.choice(
                    len(paths), size=16, replace=False)]
                traces[(n, w)] = [chosen[s:s + 4]
                                  for s in range(0, 16, 4)]
        sched = EpochSchedule.from_trace(traces, cluster)
        group = SchedulerGroup.for_schedule(cluster, sched, window_steps=2)
        assert len(group) == 4
        for step in range(4):
            group.ensure(step + 2)
            group.wait_ready(step)
            for (n, w), steps in traces.items():
                got = cluster.read_many(n, steps[step], worker_id=w)
                assert got == [files[p] for p in steps[step]]
        group.close()
        # every (node, worker) demand read hit its prefetched tier entry
        for n in range(2):
            tier = cluster.cache_tiers[n]
            for w in range(2):
                assert tier.worker_stats[w].hits == 16
        # prefetch cost accrued on BOTH nodes: no node-0 pin
        assert all(cluster.clocks[n].prefetch_s > 0 for n in range(2))


def test_schedule_spread_beats_node0_pin():
    """Multi-requester scheduling: spreading the epoch across every
    (node, worker) yields a strictly lower modeled makespan than pinning
    all reads to node 0 (the old driver behavior)."""
    files, blobs = _make_files(n=64)
    paths = sorted(files)
    sampler = _PeekableSampler(64, 16)

    def run(requesters, workers_per_node):
        spec = ClusterSpec(num_nodes=4, workers_per_node=workers_per_node,
                           cache_bytes=4 << 20, cache_policy="belady")
        cluster = FanStoreCluster.from_spec(spec)
        cluster.load_partitions(blobs)
        sched = EpochSchedule.from_sampler(
            sampler, paths, num_requesters=requesters,
            workers_per_node=workers_per_node, cluster=cluster)
        group = SchedulerGroup.for_schedule(cluster, sched, window_steps=2)
        group.run_all()
        group.close()
        for r in sched.requesters:
            node = r[0] if isinstance(r, tuple) else r
            w = r[1] if isinstance(r, tuple) else 0
            for s in sched.for_requester(r):
                cluster.read_many(node, [s.path], worker_id=w)
        return cluster.makespan_s()

    pinned = run(1, 1)           # whole epoch through node 0
    spread = run(8, 2)           # one loader per (node, worker)
    assert spread < pinned


def test_belady_future_installs_node_merged_through_tier():
    files, blobs = _make_files(n=32)
    paths = sorted(files)
    spec = ClusterSpec(num_nodes=2, workers_per_node=2,
                       cache_bytes=1 << 20, cache_policy="belady")
    cluster = FanStoreCluster.from_spec(spec)
    cluster.load_partitions(blobs)
    traces = {(0, w): [[p] for p in paths[w::2]] for w in range(2)}
    sched = EpochSchedule.from_trace(traces, cluster)
    fed = sched.install_futures(cluster)
    assert fed == 1                      # ONE shared cache per node fed once
    cache = cluster.cache_tiers[0].cache_for(0)
    assert cache is cluster.cache_tiers[0].cache_for(1)
    assert sum(len(q) for q in cache._future.values()) == len(paths)


# ---------------------------------------------------------------------------
# Cross-process ShmArena attach (spawn)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not ShmArena.available,
                    reason="multiprocessing.shared_memory unavailable")
def test_cross_process_shm_attach_round_trip():
    """The acceptance pin: a SPAWNED process rebuilds the ClusterSpec
    from JSON and reads byte-identical payloads through attached
    ShmArena segments."""
    files, blobs = _make_files(n=10)
    spec = ClusterSpec(num_nodes=2, workers_per_node=2, backend="shm")
    with FanStoreCluster.from_spec(spec) as cluster:
        cluster.transport.arena = ShmArena()
        cluster.load_partitions(blobs)
        # outputs ride the same export path as inputs
        cluster.write_file(0, "out/extra.bin", b"spawned" * 100)
        handles = {}
        for owner in range(2):
            local = [p for p in files if cluster.nodes[owner].has(p)]
            handles.update(cluster.transport.export_paths(owner, local))
        out_owner = cluster.placement.owner("out/extra.bin")
        handles.update(cluster.transport.export_paths(
            out_owner, ["out/extra.bin"]))
        ctx = multiprocessing.get_context("spawn")
        with ctx.Pool(1) as pool:
            result = pool.apply(attach_and_digest,
                                (spec.to_json(), handles))
        # the child's re-serialized spec is the identity round trip
        assert result["spec_json"] == spec.to_json()
        assert result["workers_per_node"] == 2
        expected = dict(files)
        expected["out/extra.bin"] = b"spawned" * 100
        assert set(result["digests"]) == set(handles)
        for path, digest in result["digests"].items():
            assert digest == hashlib.sha256(expected[path]).hexdigest()
            assert result["sizes"][path] == len(expected[path])


@pytest.mark.skipif(not ShmArena.available,
                    reason="multiprocessing.shared_memory unavailable")
def test_export_paths_requires_arena():
    spec = ClusterSpec(num_nodes=1, backend="shm")
    with FanStoreCluster.from_spec(spec) as cluster:
        with pytest.raises(RuntimeError, match="arena"):
            cluster.transport.export_paths(0, ["x"])
