"""FanStore cluster: global view, caching, writes, failover, broadcast."""
import numpy as np
import pytest

from repro.data.synthetic import small_file_dataset
from repro.fanstore.cluster import FanStoreCluster, InterconnectModel
from repro.fanstore.fs import FanStoreFS
from repro.fanstore.intercept import intercept
from repro.fanstore.prepare import prepare_dataset
from repro.fanstore.store import NodeStore


@pytest.fixture
def small_cluster(rng):
    files = small_file_dataset(120, (100, 2_000), num_dirs=4, seed=1)
    blobs, _ = prepare_dataset(files, 8, compress=True)
    cluster = FanStoreCluster(4)
    cluster.load_partitions(blobs, replication=2)
    return cluster, files


def test_global_view_reads(small_cluster):
    cluster, files = small_cluster
    for nid in range(4):
        for path in list(files)[::17]:
            assert cluster.read(nid, path) == files[path]


def test_metadata_replicated_readdir(small_cluster):
    cluster, files = small_cluster
    dirs = cluster.readdir("train")
    assert sorted(dirs) == sorted({p.split("/")[1] for p in files})


def test_refcount_cache_eviction():
    store = NodeStore(0, codec="none")
    from repro.fanstore.layout import pack_partition
    store.load_partition(0, pack_partition([("f.bin", b"x" * 100)]))
    d1 = store.open_local("f.bin")
    d2 = store.open_local("f.bin")
    assert store.open_files == 2 and store.stats["cache_hits"] == 1
    store.release("f.bin")
    assert store.cached_bytes == 100          # still referenced
    store.release("f.bin")
    assert store.cached_bytes == 0            # evicted at refcount 0
    assert store.stats["evictions"] == 1


def test_write_visible_on_close_and_single_write(small_cluster):
    cluster, _ = small_cluster
    cluster.write_file(1, "out/model_ep1.ckpt", b"W" * 500)
    # visible from every node, metadata on the hash-mapped node only
    for nid in range(4):
        assert cluster.read(nid, "out/model_ep1.ckpt") == b"W" * 500
    assert cluster.stat("out/model_ep1.ckpt").st_size == 500
    with pytest.raises(PermissionError):
        cluster.write_file(2, "out/model_ep1.ckpt", b"again")


def test_input_files_immutable(small_cluster):
    cluster, files = small_cluster
    path = next(iter(files))
    with pytest.raises(PermissionError):
        cluster.nodes[0].write_begin(path) if cluster.nodes[0].has(path) else \
            (_ for _ in ()).throw(PermissionError)


def test_failover_with_replication(small_cluster):
    cluster, files = small_cluster
    cluster.fail_node(2)
    assert cluster.unreachable_paths() == []
    for path in list(files)[::23]:
        assert cluster.read(0, path) == files[path]
    with pytest.raises(IOError):
        cluster.read(2, next(iter(files)))


def test_unreachable_without_replication(rng):
    files = small_file_dataset(40, (100, 500), seed=2)
    blobs, _ = prepare_dataset(files, 4, compress=False)
    cluster = FanStoreCluster(4)
    cluster.load_partitions(blobs, replication=1)
    cluster.fail_node(0)
    lost = cluster.unreachable_paths()
    assert lost                                # R=1 -> data loss on failure
    assert all(cluster.nodes[0].has(p) for p in lost)


def test_broadcast_directory_serves_locally(rng):
    files = {f"val/v{i}.bin": bytes(rng.integers(0, 9, 300, dtype=np.uint8))
             for i in range(12)}
    blobs, _ = prepare_dataset(files, 4, compress=False)
    cluster = FanStoreCluster(4)
    cluster.load_partitions(blobs, replication=1)
    assert cluster.broadcast_directory("val") == 12
    cluster.reset_clocks()
    for nid in range(4):
        for p in files:
            assert cluster.read(nid, p) == files[p]
    assert cluster.local_hit_rate() == 1.0     # all reads local after bcast


def test_fs_api_and_interception(small_cluster):
    cluster, files = small_cluster
    fs = FanStoreFS(cluster, node_id=0)
    assert fs.walk_count("/fanstore") == len(files)
    path = next(iter(files))
    with fs.open(f"/fanstore/{path}") as f:
        assert f.read() == files[path]
        f.seek(0)
        assert f.read(10) == files[path][:10]
    with intercept(fs):
        import os
        assert open(f"/fanstore/{path}", "rb").read() == files[path]
        assert os.path.exists(f"/fanstore/{path}")
        assert not os.path.exists("/fanstore/nope.bin")
        with open("/fanstore/out/gen.bin", "wb") as f:
            f.write(b"generated")
        assert open("/fanstore/out/gen.bin", "rb").read() == b"generated"


def test_least_loaded_replica_choice(rng):
    """Straggler mitigation: remote reads spread across the replica set."""
    files = {f"d/f{i}.bin": b"z" * 1000 for i in range(64)}
    blobs, _ = prepare_dataset(files, 8, compress=False)
    cluster = FanStoreCluster(4)
    cluster.load_partitions(blobs, replication=2)
    for p in files:               # node 3 reads everything
        cluster.read(3, p)
    # node 3's remote reads hit partitions whose replica set is {0, 2}
    # (placement: replicas at pid%4 and (pid+2)%4) — both should serve.
    s0, s2 = cluster.clocks[0].serve_s, cluster.clocks[2].serve_s
    assert s0 > 0 and s2 > 0
    assert max(s0, s2) < 2.0 * min(s0, s2) + 1e-9
