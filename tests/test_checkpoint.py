"""Checkpoint: atomic write, restore, retention, resume-exactness."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import build_model
from repro.train.checkpoint import (CheckpointManager, list_checkpoints,
                                    restore_checkpoint, save_checkpoint)
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_state, make_train_step


def _state_and_step(lr=5e-3):
    cfg = get_smoke("qwen2-72b")
    model = build_model(cfg)
    ocfg = OptimizerConfig(lr=lr, warmup_steps=1, total_steps=40)
    state = init_state(model, jax.random.key(0), ocfg)
    return cfg, model, ocfg, state, jax.jit(make_train_step(model, ocfg))


def test_save_restore_roundtrip(tmp_path, rng):
    cfg, model, ocfg, state, step = _state_and_step()
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 16)).astype(np.int32))}
    state, _ = step(state, batch)
    path = save_checkpoint(str(tmp_path), 1, state, extra={"note": "t"})
    assert os.path.exists(os.path.join(path, "manifest.json"))
    restored, manifest = restore_checkpoint(str(tmp_path), state)
    assert manifest["step"] == 1 and manifest["extra"]["note"] == "t"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_is_bitexact(tmp_path, rng):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    cfg, model, ocfg, s0, step = _state_and_step()
    batches = [{"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (4, 16)).astype(np.int32))}
        for _ in range(4)]
    sa = s0
    for b in batches:
        sa, ma = step(sa, b)
    sb = s0
    for b in batches[:2]:
        sb, _ = step(sb, b)
    save_checkpoint(str(tmp_path), 2, sb)
    sb2, _ = restore_checkpoint(str(tmp_path), sb)
    for b in batches[2:]:
        sb2, mb = step(sb2, b)
    assert float(ma["loss"]) == pytest.approx(float(mb["loss"]), abs=1e-6)


def test_manager_async_and_retention(tmp_path):
    cfg, model, ocfg, state, _ = _state_and_step()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    mgr.wait()
    steps = [s for s, _ in list_checkpoints(str(tmp_path))]
    assert steps == [3, 4]
    assert mgr.latest_step() == 4


def test_restore_shape_mismatch_rejected(tmp_path):
    cfg, model, ocfg, state, _ = _state_and_step()
    save_checkpoint(str(tmp_path), 1, state)
    other_cfg = get_smoke("qwen2-72b").scaled(d_model=128)
    other = build_model(other_cfg)
    other_state = init_state(other, jax.random.key(0), OptimizerConfig())
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), other_state)


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), {})
