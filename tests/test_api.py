"""FanStoreSession descriptor API, the engine write path (write_many /
streaming CheckpointWriter / write lane), cross-node write visibility
through the FS + intercept adapters, and the readdir/seek satellites."""
import io
import os

import pytest

from repro.fanstore.api import FD_BASE, CheckpointWriter, FanStoreSession
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.fs import FanStoreFS
from repro.fanstore.intercept import intercept
from repro.fanstore.prepare import prepare_dataset


def make_cluster(num_nodes, files, *, replication=1, partitions=4, **kw):
    blobs, _ = prepare_dataset(files, partitions, compress=False)
    cluster = FanStoreCluster(num_nodes, **kw)
    cluster.load_partitions(blobs, replication=replication)
    return cluster


def small_files(n=12, size=300):
    return {f"train/f{i:03d}.bin": bytes([i % 250]) * size for i in range(n)}


# ---- descriptor table -------------------------------------------------------

def test_session_fd_read_pread_lseek():
    files = small_files()
    cluster = make_cluster(2, files)
    s = FanStoreSession(cluster, 0)
    fd = s.open("/fanstore/train/f003.bin")
    assert fd >= FD_BASE
    data = files["train/f003.bin"]
    assert s.pread(fd, 10, 0) == data[:10]        # explicit offset: no cursor
    assert s.read(fd, 5) == data[:5]              # cursor advances
    assert s.lseek(fd, -5, os.SEEK_END) == len(data) - 5
    assert s.read(fd) == data[-5:]
    assert s.fstat(fd).st_size == len(data)
    s.close(fd)
    with pytest.raises(OSError):
        s.read(fd, 1)                             # EBADF after close
    assert s.open_fds == 0


def test_session_accepts_relative_and_mounted_paths():
    files = small_files()
    cluster = make_cluster(2, files)
    s = FanStoreSession(cluster, 0)
    a = s.open("train/f000.bin")
    b = s.open("/fanstore/train/f000.bin")
    assert s.read(a) == s.read(b)
    s.close(a), s.close(b)
    with pytest.raises(FileNotFoundError):
        s.open("/elsewhere/f.bin")


def test_session_write_fsync_close_visible_cross_node():
    files = small_files()
    cluster = make_cluster(4, files)
    writer = FanStoreSession(cluster, 0)
    reader = FanStoreSession(cluster, 3)
    fd = writer.open("out/gen.bin", "wb")
    writer.write(fd, b"A" * 100)
    assert not reader.exists("out/gen.bin")       # visible only on close
    assert writer.fsync(fd) == 100                # streamed to the owner
    writer.write(fd, b"B" * 50)
    st = writer.close(fd)
    assert st.st_size == 150                      # flushed + buffered
    assert reader.read_many(["out/gen.bin"])[0] == b"A" * 100 + b"B" * 50
    assert reader.getsize("/fanstore/out/gen.bin") == 150
    # single write: a second committer loses at close time
    fd2 = writer.open("out/gen.bin", "wb")
    writer.write(fd2, b"clobber")
    with pytest.raises(PermissionError):
        writer.close(fd2)
    assert reader.read_many(["out/gen.bin"])[0][:1] == b"A"


def test_abort_drops_fsynced_staging():
    """Regression: an aborted write's already-fsync'd chunks must not leak
    into a later writer's commit of the same path."""
    cluster = make_cluster(2, small_files())
    s = FanStoreSession(cluster, 0)
    fd = s.open("out/ck.bin", "wb")
    s.write(fd, b"OLD!")
    s.fsync(fd)                                   # chunk staged at the owner
    s.abort(fd)
    assert not s.exists("out/ck.bin")
    fd = s.open("out/ck.bin", "wb")
    s.write(fd, b"NEW-PAYLOAD")
    st = s.close(fd)
    assert st.st_size == 11
    assert s.read_many(["out/ck.bin"])[0] == b"NEW-PAYLOAD"
    # close_all takes the same path (session as context manager)
    with FanStoreSession(cluster, 1) as s1:
        fd = s1.open("out/ck2.bin", "wb")
        s1.write(fd, b"half")
        s1.fsync(fd)
    FanStoreSession(cluster, 1).write_many([("out/ck2.bin", b"whole")])
    assert cluster.read(0, "out/ck2.bin") == b"whole"


def test_seek_back_write_rejected():
    """Regression: lseek-then-write on a write fd must error, not silently
    append (same contract pwrite enforces for explicit offsets)."""
    cluster = make_cluster(2, small_files())
    s = FanStoreSession(cluster, 0)
    fd = s.open("out/h.bin", "wb")
    s.write(fd, b"HEADER00")
    s.lseek(fd, 0, os.SEEK_SET)
    with pytest.raises(io.UnsupportedOperation):
        s.write(fd, b"HEADER99")
    s.lseek(fd, 0, os.SEEK_CUR)                   # restoring the cursor is OK
    s.lseek(fd, 8, os.SEEK_SET)
    s.write(fd, b"!")
    assert s.close(fd).st_size == 9


def test_session_pwrite_appends_only():
    cluster = make_cluster(2, small_files())
    s = FanStoreSession(cluster, 0)
    fd = s.open("out/w.bin", "wb")
    assert s.pwrite(fd, b"xxxx", 0) == 4
    assert s.pwrite(fd, b"yy", 4) == 2            # offset == size: OK
    with pytest.raises(io.UnsupportedOperation):
        s.pwrite(fd, b"z", 1)                     # holes/overwrites rejected
    with pytest.raises(io.UnsupportedOperation):
        s.lseek(fd, 0, os.SEEK_END)               # size undefined until close
    s.close(fd)
    assert s.read_many(["out/w.bin"])[0] == b"xxxxyy"


def test_session_payload_lands_on_placement_owner():
    """End-to-end ring routing: the committed payload lives on the
    placement owner's output tier, not stranded on the writer."""
    cluster = make_cluster(4, small_files())
    s = FanStoreSession(cluster, 1)
    s.write_many([(f"out/o{i}.bin", bytes([i]) * 64) for i in range(8)])
    for i in range(8):
        path = f"out/o{i}.bin"
        owner = cluster.placement.owner(path)
        assert cluster.nodes[owner].has_output(path)
        for nid in range(4):
            if nid != owner:
                assert not cluster.nodes[nid].has_output(path)
        # reads are served by the owner (remote for everyone else)
        st, loc = cluster.output_ns.lookup(path)
        assert loc.node_id == owner and st.st_size == 64


# ---- batched write path -----------------------------------------------------

def test_write_many_one_round_trip_per_owner_pair():
    """K files bound for one owner accrue exactly one latency_s on the
    writer's write lane — the mirror of read_many's coalescing."""
    cluster = FanStoreCluster(4)
    net = cluster.net
    entries = [(f"out/b{i:02d}.bin", b"z" * 1000) for i in range(16)]
    owners = {p: cluster.placement.owner(p) for p, _ in entries}
    remote_groups = {o for o in owners.values() if o != 1}
    cluster.write_many(1, entries)
    clock = cluster.clocks[1]
    local_bytes = sum(len(d) for p, d in entries if owners[p] == 1)
    remote_bytes = sum(len(d) for p, d in entries if owners[p] != 1)
    n_local = sum(1 for o in owners.values() if o == 1)
    expect = (len(remote_groups) * net.latency_s
              + remote_bytes / net.bandwidth_Bps
              + n_local * net.open_overhead_s
              + local_bytes / net.disk_bw_Bps)
    assert abs(clock.write_s - expect) < 1e-12
    assert clock.write_bytes == 16 * 1000
    assert clock.consume_s == 0.0                 # nothing on the demand lane


def test_write_many_cheaper_than_perfile_loop_at_8_nodes():
    """Acceptance pin: batched write_many strictly beats the per-file
    write_file loop at >= 8 nodes (engine level)."""
    payload = bytes(4096)
    a = FanStoreCluster(8)
    b = FanStoreCluster(8)
    for nid in range(8):
        entries = [(f"out/n{nid}/f{i:03d}.bin", payload) for i in range(16)]
        a.write_many(nid, entries)
        for p, d in entries:
            b.write_file(nid, p, d)
    assert a.makespan_s() < b.makespan_s()
    # and through the benchmark arm
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.io_scaling import CPU_NET, run_write_one
    wm = run_write_one(8, 8192, 16, CPU_NET, batched=True)
    wp = run_write_one(8, 8192, 16, CPU_NET, batched=False)
    assert wm["makespan_s"] < wp["makespan_s"]


def test_write_many_async_future_and_errors():
    cluster = FanStoreCluster(3)
    fut = cluster.write_many_async(0, [("out/a.bin", b"x" * 10)])
    assert fut.result(timeout=30)[0].st_size == 10
    with pytest.raises(ValueError):
        cluster.write_many(0, [("out/d.bin", b"1"), ("out/d.bin", b"2")])
    with pytest.raises(PermissionError):
        cluster.write_many(1, [("out/a.bin", b"again")])
    cluster.shutdown()


def test_write_many_rejects_immutable_inputs():
    files = small_files()
    cluster = make_cluster(2, files, partitions=1)
    owner_node = 0 if cluster.nodes[0].has("train/f000.bin") else 1
    with pytest.raises(PermissionError):
        cluster.write_many(owner_node, [("train/f000.bin", b"overwrite")])


# ---- streaming checkpoint writer -------------------------------------------

def test_checkpoint_writer_chunks_on_write_lane():
    cluster = make_cluster(2, small_files())
    s = FanStoreSession(cluster, 0)
    w = s.checkpoint_writer(chunk_bytes=256)
    payload = bytes(range(256)) * 5               # 1280 B -> 5 chunks
    st = w.write_shard("ckpt/step_1/shard_0.npy", payload)
    assert st.st_size == len(payload)
    assert w.chunks_flushed == 5 and w.shards_written == 1
    assert s.read_many(["ckpt/step_1/shard_0.npy"])[0] == payload
    # every byte rode the concurrent write lane, not the demand lane
    assert cluster.clocks[0].write_bytes == len(payload)
    assert cluster.clocks[0].write_s > 0.0


def test_checkpoint_overlap_beats_serialized():
    """Acceptance pin: a shard flush overlapped with an active prefetch
    window yields strictly lower epoch makespan than serialized
    write-then-prefetch."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.io_scaling import CPU_NET, run_checkpoint_overlap
    r = run_checkpoint_overlap(8, 64 * 1024, 128, CPU_NET,
                               reads_per_node=64, shard_bytes=1 << 20,
                               chunk_bytes=1 << 18)
    assert r["overlapped_makespan_s"] < r["serialized_makespan_s"]
    assert r["overlap_speedup"] > 1.0


def test_session_checkpoint_save_restore_roundtrip():
    import numpy as np
    from repro.train.checkpoint import (list_session_checkpoints,
                                        restore_from_session,
                                        save_to_session)
    cluster = make_cluster(2, small_files())
    s = FanStoreSession(cluster, 0)
    state = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
             "opt": {"mu": np.ones((5,), dtype=np.float32)}}
    save_to_session(s, 10, state, extra={"sampler_step": 7})
    save_to_session(s, 20, state)
    assert [st for st, _ in list_session_checkpoints(s)] == [10, 20]
    target = {"w": np.zeros((3, 4), dtype=np.float32),
              "opt": {"mu": np.zeros((5,), dtype=np.float32)}}
    restored, manifest = restore_from_session(s, target, step=10)
    np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
    assert manifest["extra"]["sampler_step"] == 7
    with pytest.raises(PermissionError):
        save_to_session(s, 10, state)             # checkpoints are immutable


# ---- readdir satellite: outputs list everywhere -----------------------------

def test_written_files_appear_in_readdir_and_walk():
    files = small_files()
    cluster = make_cluster(3, files)
    s = FanStoreSession(cluster, 1)
    before = s.walk_count()
    with pytest.raises(FileNotFoundError):
        cluster.readdir("out")
    s.write_many([("out/preds/a.bin", b"x"), ("out/preds/b.bin", b"y")])
    assert cluster.readdir("out") == ["preds"]
    assert cluster.readdir("out/preds") == ["a.bin", "b.bin"]
    assert "out" in cluster.readdir("")           # parent dirs materialize
    assert cluster.is_dir("out/preds")
    assert s.walk_count() == before + 2
    # merged listing: inputs and outputs under one root
    assert set(s.listdir("")) >= {"train", "out"}


def test_scandir_entries_cover_both_namespaces():
    files = small_files(4)
    cluster = make_cluster(2, files)
    s = FanStoreSession(cluster, 0)
    s.write_many([("train_out.bin", b"q" * 9)])
    entries = {e.name: e for e in s.scandir("/fanstore")}
    assert entries["train"].is_dir()
    assert entries["train_out.bin"].is_file()
    assert entries["train_out.bin"].stat().st_size == 9
    assert entries["train"].path == "/fanstore/train"


# ---- FS adapter seek satellite ---------------------------------------------

def test_fs_seek_invalid_whence_and_seek_end_on_write():
    files = small_files(4)
    cluster = make_cluster(2, files)
    fs = FanStoreFS(cluster, node_id=0)
    with fs.open("/fanstore/train/f000.bin") as f:
        with pytest.raises(ValueError):
            f.seek(0, 3)                          # nonstandard whence
        assert f.seek(-10, os.SEEK_END) == 290
    f = fs.open("/fanstore/out/w.bin", "wb")
    f.write(b"abc")
    with pytest.raises(ValueError):
        f.seek(0, 99)
    with pytest.raises(io.UnsupportedOperation):
        f.seek(0, os.SEEK_END)                    # size undefined mid-write
    f.close()


# ---- cross-node visibility through FS / intercept ---------------------------

def test_cross_node_write_visibility_through_fs_and_intercept():
    """Write on node A via intercepted open(..., 'wb'); read + stat +
    listdir on node B; second writer gets PermissionError."""
    files = small_files()
    cluster = make_cluster(4, files)
    fs_a = FanStoreFS(cluster, node_id=0)
    fs_b = FanStoreFS(cluster, node_id=2)
    with intercept(fs_a):
        with open("/fanstore/out/epoch1/model.bin", "wb") as f:
            f.write(b"M" * 333)
    with intercept(fs_b):
        assert open("/fanstore/out/epoch1/model.bin", "rb").read() == b"M" * 333
        assert os.stat("/fanstore/out/epoch1/model.bin").st_size == 333
        assert os.listdir("/fanstore/out/epoch1") == ["model.bin"]
        assert os.listdir("/fanstore/out") == ["epoch1"]
        assert os.path.getsize("/fanstore/out/epoch1/model.bin") == 333
        with pytest.raises(PermissionError):
            with open("/fanstore/out/epoch1/model.bin", "wb") as f:
                f.write(b"clobber")
    # the committed payload survived the losing writer
    assert cluster.read(1, "out/epoch1/model.bin") == b"M" * 333


def test_fd_level_intercept_roundtrip():
    files = small_files()
    cluster = make_cluster(3, files)
    s = FanStoreSession(cluster, 1)
    with intercept(s):
        fd = os.open("/fanstore/out/fd.bin", os.O_WRONLY | os.O_CREAT)
        assert fd >= FD_BASE
        assert os.write(fd, b"hello ") == 6
        assert os.write(fd, b"world") == 5
        os.close(fd)
        fd = os.open("/fanstore/out/fd.bin", os.O_RDONLY)
        assert os.read(fd, 5) == b"hello"
        assert os.fstat(fd).st_size == 11         # stat-by-descriptor
        assert os.lseek(fd, 6, os.SEEK_SET) == 6
        assert os.read(fd, 100) == b"world"
        os.close(fd)
        # os.walk over the mount uses intercepted scandir
        seen = {root: sorted(names)
                for root, _, names in os.walk("/fanstore/out")}
        assert seen["/fanstore/out"] == ["fd.bin"]
        # real fds still work through the patched os.* entry points
        rfd = os.open(os.devnull, os.O_RDONLY)
        assert rfd < FD_BASE
        os.read(rfd, 1)
        os.close(rfd)
    assert cluster.read(0, "out/fd.bin") == b"hello world"


def test_session_write_visible_from_prefetch_loader_consumers():
    """The whole surface hangs together: a session write is readable via
    read_many on another node's session in the same batch as inputs."""
    files = small_files()
    cluster = make_cluster(2, files)
    FanStoreSession(cluster, 0).write_many([("out/extra.bin", b"E" * 20)])
    out = FanStoreSession(cluster, 1).read_many(
        ["train/f000.bin", "out/extra.bin"])
    assert out[0] == files["train/f000.bin"]
    assert out[1] == b"E" * 20
