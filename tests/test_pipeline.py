"""PrefetchLoader: ordering, overlap, error propagation, checkpoint cursor."""
import threading
import time

import numpy as np
import pytest

from repro.data.pipeline import PrefetchLoader
from repro.data.sampler import GlobalUniformSampler


def _mk(num=64, gb=8, threads=4, fetch=None, seed=0):
    sampler = GlobalUniformSampler(num, gb, seed=seed)
    fetch = fetch or (lambda i: i.to_bytes(4, "little"))
    decode = lambda blobs: np.array(
        [int.from_bytes(b, "little") for b in blobs])
    return PrefetchLoader(sampler, fetch, decode, num_threads=threads)


def test_batches_match_sampler():
    ref = GlobalUniformSampler(64, 8, seed=0)
    loader = _mk(seed=0)
    out = list(loader.batches(6))
    for got in out:
        assert (got == ref.next_batch()).all()


def test_prefetch_overlaps_io():
    """With 4 threads + depth-2 staging, wall time << serial fetch time."""
    delay = 0.004
    def slow_fetch(i):
        time.sleep(delay)
        return i.to_bytes(4, "little")
    loader = _mk(threads=4, fetch=slow_fetch)
    t0 = time.perf_counter()
    consumed = 0
    for batch in loader.batches(6):
        time.sleep(delay * 2)      # simulated compute
        consumed += len(batch)
    wall = time.perf_counter() - t0
    serial = 6 * 8 * delay + 6 * 2 * delay
    assert consumed == 48
    assert wall < serial * 0.8


def test_error_propagates():
    def bad_fetch(i):
        if i == 13:
            raise IOError("node down")
        return i.to_bytes(4, "little")
    loader = _mk(num=16, gb=16, fetch=bad_fetch)
    with pytest.raises(IOError):
        list(loader.batches(1))


def test_cursor_is_sampler_state():
    loader = _mk()
    list(loader.batches(3))
    assert loader.cursor.step == 3


def test_error_after_close_surfaces_on_close():
    """Regression: an exception raised inside the producer after close()
    used to be swallowed; close() must re-raise it."""
    release = threading.Event()

    def blocking_fetch_many(idxs):
        release.wait(timeout=5)
        raise IOError("owner died mid-window")

    sampler = GlobalUniformSampler(64, 8, seed=0)
    loader = PrefetchLoader(sampler, fetch_many=blocking_fetch_many,
                            decode=lambda b: b)
    loader.start(4)
    # the consumer walks away while a fetch is in flight; the fetch fails
    # only after close() has begun waiting on the producer
    threading.Timer(0.05, release.set).start()
    with pytest.raises(IOError, match="owner died"):
        loader.close()
    # already-surfaced errors are not raised twice
    loader.close()


def test_error_surfaces_on_next_not_just_at_end():
    calls = []

    def fetch_many(idxs):
        calls.append(1)
        if len(calls) >= 2:
            raise IOError("second batch failed")
        return [b"x"] * len(idxs)

    sampler = GlobalUniformSampler(64, 8, seed=0)
    loader = PrefetchLoader(sampler, fetch_many=fetch_many,
                            decode=lambda b: b)
    loader.start(4)
    assert next(loader) == [b"x"] * 8
    with pytest.raises(IOError, match="second batch"):
        next(loader)


def test_stop_alias_propagates_error():
    def bad_fetch_many(idxs):
        raise RuntimeError("boom")

    sampler = GlobalUniformSampler(64, 8, seed=0)
    loader = PrefetchLoader(sampler, fetch_many=bad_fetch_many,
                            decode=lambda b: b)
    loader.start(2)
    time.sleep(0.05)                # let the producer hit the error
    with pytest.raises(RuntimeError):
        loader.stop()
