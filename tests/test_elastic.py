"""Elasticity: rebalance planning, replica repair, batch rescale."""
import numpy as np
import pytest

from repro.data.synthetic import small_file_dataset
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.prepare import prepare_dataset
from repro.train.elastic import (RebalancePlan, apply_rebalance,
                                 execute_rebalance, plan_rebalance,
                                 rescale_batch)


def _cluster(nodes=6, parts=12, replication=2, seed=0):
    files = small_file_dataset(60, (50, 400), seed=seed)
    blobs, _ = prepare_dataset(files, parts, compress=False)
    c = FanStoreCluster(nodes)
    c.load_partitions(blobs, replication=replication)
    return c, files


def test_plan_noop_when_healthy():
    c, _ = _cluster()
    plan = plan_rebalance(c, target_replication=2)
    assert plan.re_replicate == [] and plan.lost_partitions == []


def test_repair_after_failure_restores_reads():
    c, files = _cluster()
    c.fail_node(1)
    plan = plan_rebalance(c, target_replication=2)
    assert plan.lost_partitions == []
    assert plan.re_replicate                       # deficit exists
    assert all(dst != 1 for _, dst in plan.re_replicate)
    made = apply_rebalance(c, plan)
    assert made == len(plan.re_replicate)
    # now fail a second node: R=2 restored means still zero unreachable
    c.fail_node(2)
    assert c.unreachable_paths() == []
    for p in list(files)[::13]:
        assert c.read(0, p) == files[p]


def test_bytes_moved_fraction_is_a_fraction():
    # regression: this used to return len(moves) — a COUNT, not a
    # fraction, so a 3-move plan over 100 partitions reported 3.0
    plan = RebalancePlan(moves=[(0, 1, 2), (5, 1, 3), (9, 1, 4)],
                         re_replicate=[(2, 3)], lost_partitions=[],
                         total_partitions=12)
    assert plan.bytes_moved_fraction == pytest.approx(3 / 12)
    assert plan.re_replicate_fraction == pytest.approx(1 / 12)
    empty = RebalancePlan(moves=[], re_replicate=[], lost_partitions=[])
    assert empty.bytes_moved_fraction == 0.0
    assert empty.re_replicate_fraction == 0.0


def test_planned_fractions_stay_small_after_one_failure():
    # the consistent-hashing selling point: repairing ONE failed node out
    # of six re-replicates only that node's share, not the whole set
    c, _ = _cluster()
    c.fail_node(1)
    plan = plan_rebalance(c, target_replication=2)
    assert plan.total_partitions == 12
    assert 0.0 < plan.re_replicate_fraction <= 0.5


def test_execute_rebalance_repairs_metadata_replica_sets():
    c, files = _cluster()
    c.fail_node(1)
    plan = plan_rebalance(c, target_replication=2)
    made = execute_rebalance(c, plan)
    assert made == len(plan.re_replicate)
    # the repair is visible to the ROUTING layer, not just the stores:
    # every file has >= 2 live owners in its metadata replica set
    for path in files:
        _, loc = c.metadata.lookup(path)
        live = [o for o in loc.all_owners if o not in c.failed]
        assert len(set(live)) >= 2, path


def test_heal_re_replicates_outputs_and_survives_owner_loss():
    # the PR-7 debt: committed outputs were single-owner, so losing the
    # placement owner lost the checkpoint. heal() must now restore R=2
    # for outputs too, and reads must fail over to the new copy.
    from repro.train.checkpoint import restore_from_session, save_to_session
    c, _ = _cluster()
    sess = c.connect(0, 0)
    state = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
             "b": np.ones(4, dtype=np.float32)}
    save_to_session(sess, 7, state)
    plan = plan_rebalance(c, target_replication=2)
    assert plan.re_replicate_outputs          # every output is R=1 so far
    assert plan.lost_outputs == []
    made = execute_rebalance(c, plan)
    assert made >= len(plan.re_replicate_outputs)
    # every committed output now has two live payload holders, and the
    # replica set is visible to the routing layer
    for path in c.output_ns.paths():
        _, loc = c.output_ns.lookup(path)
        holders = [o for o in loc.all_owners if c.nodes[o].has_output(path)]
        assert len(set(holders)) >= 2, path
    # kill the PRIMARY owner of one checkpoint shard; the restore must
    # come back byte-identical from the surviving replica
    some_path = next(iter(c.output_ns.paths()))
    _, loc = c.output_ns.lookup(some_path)
    c.fail_node(loc.node_id)
    target = {"w": np.zeros((3, 4), dtype=np.float32),
              "b": np.zeros(4, dtype=np.float32)}
    reader = c.connect([n for n in c.live_nodes()][0], 0)
    restored, manifest = restore_from_session(reader, target)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(restored["w"], state["w"])
    np.testing.assert_array_equal(restored["b"], state["b"])
    # and healing AGAIN brings the outputs back to R=2 on the survivors
    plan2 = plan_rebalance(c, target_replication=2)
    assert plan2.lost_outputs == []
    execute_rebalance(c, plan2)
    for path in c.output_ns.paths():
        _, loc = c.output_ns.lookup(path)
        live = [o for o in loc.all_owners if o not in c.failed]
        assert len(set(live)) >= 2, path


def test_unlink_reclaims_all_output_replicas():
    # replicated outputs must unlink everywhere, or a rewrite of the
    # freed name could serve stale bytes from a surviving replica
    c, _ = _cluster()
    sess = c.connect(0, 0)
    sess.write_many([("out/result.bin", b"v1" * 100)])
    c.heal(target_replication=2)
    _, loc = c.output_ns.lookup("out/result.bin")
    holders = list(loc.all_owners)
    assert len(set(holders)) == 2
    sess.unlink("out/result.bin")
    for o in holders:
        assert not c.nodes[o].has_output("out/result.bin")
    # the freed name is writable again and serves the NEW bytes
    sess.write_many([("out/result.bin", b"v2")])
    assert c.read(0, "out/result.bin") == b"v2"


def test_lost_partition_detected():
    c, _ = _cluster(replication=1)
    c.fail_node(0)
    plan = plan_rebalance(c, target_replication=1)
    assert plan.lost_partitions                    # R=1 cannot self-heal


def test_rescale_batch_shrink_keeps_global():
    plan = rescale_batch(256, old_workers=32, new_workers=16,
                         old_microbatches=1)
    assert plan.effective_batch == 256
    assert plan.microbatches == 2                  # grad accumulation doubles


def test_rescale_batch_grow():
    plan = rescale_batch(256, old_workers=16, new_workers=32,
                         old_microbatches=2)
    assert plan.effective_batch == 256
    assert plan.num_workers == 32


def test_rescale_indivisible_raises():
    with pytest.raises(ValueError):
        rescale_batch(100, old_workers=4, new_workers=7)
