"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device tests spawn subprocesses (tests/test_multidevice.py)."""
import threading

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _fanstore_threads():
    return {t for t in threading.enumerate()
            if t.is_alive() and t.name.startswith("fanstore")}


@pytest.fixture(autouse=True)
def no_leaked_fanstore_threads():
    """Every transport thread (I/O pool workers, socket serving loops,
    connection handlers — all named ``fanstore-*``) must be torn down by
    the test that spawned it: use the cluster as a context manager or
    call ``cluster.close()``. Leaked pools outlive the test session and
    leaked serving loops can hang CI, so the leaking test fails here.
    ``close()`` joins everything (shutdown(wait=True) / thread joins), so
    anything still alive after the test body IS a leak, not a race."""
    before = _fanstore_threads()
    yield
    leaked = _fanstore_threads() - before
    assert not leaked, (
        "test leaked transport threads: "
        f"{sorted(t.name for t in leaked)} — close the cluster "
        "(with FanStoreCluster(...) as c: / c.close())")
