"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.codec import block_dequantize_host, block_quantize
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# dequant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,f,qblock,bn,bf", [
    (8, 256, 256, 8, 256),
    (64, 1024, 256, 32, 512),
    (32, 512, 128, 16, 256),
    (128, 2048, 256, 128, 2048),
])
def test_dequant_shapes(rng, n, f, qblock, bn, bf):
    x = rng.standard_normal((n, f)).astype(np.float32) * 3
    q, s = block_quantize(x, block=qblock)
    from repro.kernels.dequant import dequant
    out = dequant(jnp.asarray(q), jnp.asarray(s), block_n=bn, block_f=bf,
                  qblock=qblock, out_dtype=jnp.float32, interpret=True)
    host = block_dequantize_host(q, s, block=qblock)
    np.testing.assert_allclose(np.asarray(out), host, rtol=1e-5, atol=1e-5)
    # quantization error bounded by scale/2 per element
    scales = np.repeat(s.astype(np.float32), qblock, axis=1)
    assert (np.abs(host - x) <= scales * 0.5 + 1e-6).all()


@pytest.mark.parametrize("out_dtype", [jnp.bfloat16, jnp.float32])
def test_dequant_dtypes(rng, out_dtype):
    x = rng.standard_normal((16, 512)).astype(np.float32)
    q, s = block_quantize(x)
    out = ops.dequant(jnp.asarray(q), jnp.asarray(s), impl="interpret",
                      out_dtype=out_dtype)
    assert out.dtype == out_dtype
    ref_out = ref.dequant_ref(jnp.asarray(q), jnp.asarray(s),
                              out_dtype=out_dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32),
                               rtol=1e-2, atol=1e-2)


def test_int4_host_codec(rng):
    x = rng.standard_normal((8, 512)).astype(np.float32)
    q4, s = block_quantize(x, bits=4)
    assert q4.shape == (8, 256)
    out = block_dequantize_host(q4, s, bits=4)
    scales = np.repeat(s.astype(np.float32), 256, axis=1)
    assert (np.abs(out - x) <= scales * 0.5 + 1e-6).all()


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,d,s,bd,tc", [
    (1, 32, 16, 8, 16, 32),
    (2, 64, 32, 8, 16, 16),
    (2, 128, 64, 16, 32, 32),
])
def test_ssm_scan_shapes(rng, b, t, d, s, bd, tc):
    u = rng.standard_normal((b, t, d)).astype(np.float32)
    dt = (rng.random((b, t, d)) * 0.3).astype(np.float32)
    b_in = rng.standard_normal((b, t, s)).astype(np.float32)
    c_in = rng.standard_normal((b, t, s)).astype(np.float32)
    a_log = np.log(np.tile(np.arange(1, s + 1, dtype=np.float32)[None],
                           (d, 1)))
    d_skip = rng.standard_normal(d).astype(np.float32)
    args = list(map(jnp.asarray, (u, dt, b_in, c_in, a_log, d_skip)))
    yk, hk = ops.ssm_scan(*args, impl="interpret", block_d=bd, time_chunk=tc)
    yr, hr = ref.ssm_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=3e-5, atol=3e-5)


def test_ssm_scan_bf16_inputs(rng):
    b, t, d, s = 1, 32, 16, 8
    u = jnp.asarray(rng.standard_normal((b, t, d)), jnp.bfloat16)
    dt = jnp.asarray(rng.random((b, t, d)) * 0.2, jnp.bfloat16)
    b_in = jnp.asarray(rng.standard_normal((b, t, s)), jnp.bfloat16)
    c_in = jnp.asarray(rng.standard_normal((b, t, s)), jnp.bfloat16)
    a_log = jnp.asarray(np.zeros((d, s)), jnp.float32)
    d_skip = jnp.ones((d,), jnp.float32)
    yk, hk = ops.ssm_scan(u, dt, b_in, c_in, a_log, d_skip, impl="interpret",
                          block_d=16, time_chunk=16)
    yr, hr = ref.ssm_scan_ref(u, dt, b_in, c_in, a_log, d_skip)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=2e-2, atol=2e-2)


# also cross-check the lax chunked scan used by the models
def test_selective_scan_lax_vs_ref(rng):
    from repro.models.mamba import selective_scan
    b, t, d, s = 2, 96, 24, 8
    u = rng.standard_normal((b, t, d)).astype(np.float32)
    dt = (rng.random((b, t, d)) * 0.3).astype(np.float32)
    b_in = rng.standard_normal((b, t, s)).astype(np.float32)
    c_in = rng.standard_normal((b, t, s)).astype(np.float32)
    a_log = np.zeros((d, s), np.float32)
    d_skip = np.ones(d, np.float32)
    args = list(map(jnp.asarray, (u, dt, b_in, c_in, a_log, d_skip)))
    y1, h1 = selective_scan(*args, chunk=32)
    y2, h2 = ref.ssm_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,kv,dh,dv,win,bq,bk", [
    (2, 128, 4, 2, 32, 32, None, 64, 64),
    (1, 256, 4, 4, 64, 64, 64, 64, 64),
    (2, 128, 8, 2, 48, 24, None, 64, 64),   # MLA-style dv != dh
    (1, 128, 2, 1, 32, 32, 32, 32, 32),     # tight window
    (1, 64, 4, 4, 128, 128, None, 64, 64),
])
def test_flash_attn_sweep(rng, b, t, h, kv, dh, dv, win, bq, bk):
    q = rng.standard_normal((b, t, h, dh)).astype(np.float32)
    k = rng.standard_normal((b, t, kv, dh)).astype(np.float32)
    v = rng.standard_normal((b, t, kv, dv)).astype(np.float32)
    out = ops.attention(*map(jnp.asarray, (q, k, v)), causal=True, window=win,
                        impl="interpret", block_q=bq, block_k=bk)
    expect = ref.attention_ref(*map(jnp.asarray, (q, k, v)), causal=True,
                               window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_attn_bf16(rng):
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.bfloat16)
    out = ops.attention(q, k, v, impl="interpret", block_q=64, block_k=64)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_attention_lax_matches_ref(rng):
    from repro.models.layers import flash_attention_lax
    for win in (None, 40):
        q = rng.standard_normal((2, 96, 4, 32)).astype(np.float32)
        k = rng.standard_normal((2, 96, 2, 32)).astype(np.float32)
        v = rng.standard_normal((2, 96, 2, 32)).astype(np.float32)
        a1 = flash_attention_lax(*map(jnp.asarray, (q, k, v)), causal=True,
                                 window=win, block_q=32, block_k=32)
        a2 = ref.attention_ref(*map(jnp.asarray, (q, k, v)), causal=True,
                               window=win)
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                                   rtol=2e-4, atol=2e-4)
