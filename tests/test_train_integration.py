"""Integration: full data plane -> train loop; loss decreases; grad comm."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data.pipeline import PrefetchLoader
from repro.data.sampler import GlobalUniformSampler
from repro.data.synthetic import files_to_tokens, token_dataset, tokens_to_files
from repro.fanstore import FanStoreCluster, prepare_dataset
from repro.models import build_model
from repro.train.grad_comm import quantize_ef
from repro.train.optimizer import (OptimizerConfig, adamw_init, adamw_update,
                                   global_norm, lr_schedule)
from repro.train.train_step import init_state, make_train_step


def test_end_to_end_fanstore_training(rng):
    seq, vocab = 32, 128
    tokens = token_dataset(128, seq, vocab, seed=0)
    files = tokens_to_files(tokens)
    blobs, _ = prepare_dataset(files, 8, compress=True)
    cluster = FanStoreCluster(4, codec="lzss")
    cluster.load_partitions(blobs, replication=2)
    paths = sorted(files)

    cfg = get_smoke("chatglm3-6b").scaled(vocab_size=vocab)
    model = build_model(cfg)
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    state = init_state(model, jax.random.key(0), ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    sampler = GlobalUniformSampler(len(paths), 16, seed=0)
    loader = PrefetchLoader(
        sampler, fetch=lambda i: cluster.read(i % 4, paths[i]),
        decode=lambda bl: {"tokens": jnp.asarray(files_to_tokens(bl, seq))},
        num_threads=4)
    losses = []
    for batch in loader.batches(15):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1
    assert cluster.local_hit_rate() > 0.3       # replication=2 on 4 nodes


def test_microbatching_equivalence(rng):
    """2-way grad accumulation == single big batch (same loss trajectory)."""
    cfg = get_smoke("qwen2-72b").scaled(remat=False)
    model = build_model(cfg)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                           grad_clip=0.0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (8, 32)).astype(np.int32))}
    s1 = init_state(model, jax.random.key(0), ocfg)
    s2 = init_state(model, jax.random.key(0), ocfg)
    f1 = jax.jit(make_train_step(model, ocfg, microbatches=1))
    f2 = jax.jit(make_train_step(model, ocfg, microbatches=2))
    for _ in range(3):
        s1, m1 = f1(s1, batch)
        s2, m2 = f2(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=5e-3)


def test_lr_schedule_shapes():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          schedule="cosine", min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < 0.2                      # warmup start
    assert max(lrs) == pytest.approx(1.0, abs=1e-3)
    assert lrs[-1] == pytest.approx(0.1, abs=0.05)
    assert np.argmax(lrs) <= 10


def test_grad_clip_bounds_update():
    cfg = OptimizerConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4, 4), 100.0)}
    _, _, metrics = adamw_update(cfg, params, grads, opt)
    assert float(metrics["grad_norm"]) == pytest.approx(400.0)


def test_quantize_ef_unbiased_over_time(rng):
    """Error feedback: accumulated quantized sum tracks the true sum."""
    x = jnp.asarray(rng.standard_normal((4, 256)) * 0.01)
    res = jnp.zeros_like(x)
    q_sum = np.zeros(x.shape, np.float32)
    for t in range(50):
        q, scale, res = quantize_ef(x, res)
        q_sum += np.asarray(q, np.float32) * np.asarray(scale)
    true_sum = np.asarray(x) * 50
    # per-element error stays bounded by one quantization step, not 50
    step = np.asarray(jnp.max(jnp.abs(x), axis=-1, keepdims=True)) / 127
    assert (np.abs(q_sum - true_sum) <= step * 1.5 + 1e-7).all()


def test_zero1_shardings_api():
    from repro.train.optimizer import zero1_leaf_sharding
    import jax.sharding as shd
    # single-device "mesh" exercise of the spec logic
    mesh = jax.make_mesh((1,), ("data",))
    fn = zero1_leaf_sharding(mesh, ("data",))
    ns = shd.NamedSharding(mesh, shd.PartitionSpec(None, None))
    leaf = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    out = fn(ns, leaf)
    assert isinstance(out, shd.NamedSharding)
