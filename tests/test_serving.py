"""Serving plane: admission control, DRR fairness, per-tenant
attribution, hot-shard promotion, and the multi-tenant session surface."""
import threading
import time

import numpy as np
import pytest

from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.layout import pack_partition
from repro.fanstore.placement import ShardPopularity
from repro.fanstore.serving import (AdmissionGate, AdmissionShed, ServeGroup,
                                    TenantSession)
from repro.fanstore.spec import ClusterSpec


def _packed_cluster(spec, *, num_files=64, per_part=8, file_size=2048):
    """Contiguously packed partitions (partition 0 holds files 0..per_part)
    so a head-concentrated trace has an actual hot shard."""
    payload = bytes(range(256)) * (file_size // 256)
    parts = [pack_partition(
        [(f"serve/f{i:03d}.bin", payload)
         for i in range(p * per_part, (p + 1) * per_part)], compress=False)
        for p in range(num_files // per_part)]
    c = FanStoreCluster.from_spec(spec)
    c.load_partitions(parts)
    return c, payload


# ---- spec knobs -------------------------------------------------------------

def test_spec_serving_knob_validation():
    with pytest.raises(ValueError):
        ClusterSpec(num_nodes=2, max_inflight_bytes=-1)
    with pytest.raises(ValueError):
        ClusterSpec(num_nodes=2, serve_queue_depth=0)
    with pytest.raises(ValueError):
        ClusterSpec(num_nodes=2, serve_quantum_bytes=0)
    with pytest.raises(ValueError):
        ClusterSpec(num_nodes=2, hot_shard_threshold=-1)
    with pytest.raises(ValueError):
        ClusterSpec(num_nodes=2, hot_shard_replication=0)
    # promotion enabled: the replica target must fit the topology
    with pytest.raises(ValueError):
        ClusterSpec(num_nodes=2, hot_shard_threshold=4,
                    hot_shard_replication=3)
    # promotion DISABLED: the default replication target is inert, so a
    # single-node spec stays constructible
    assert ClusterSpec(num_nodes=1).hot_shard_replication == 2


def test_spec_serving_knobs_round_trip():
    spec = ClusterSpec(num_nodes=4, max_inflight_bytes=1 << 20,
                       serve_queue_depth=64, serve_quantum_bytes=4096,
                       hot_shard_threshold=16, hot_shard_replication=3)
    again = ClusterSpec.from_json(spec.to_json())
    assert again == spec
    assert again.max_inflight_bytes == 1 << 20
    assert again.hot_shard_replication == 3


# ---- admission gate ---------------------------------------------------------

def test_gate_caps_inflight_under_thread_storm():
    cap = 4096
    gate = AdmissionGate(cap, quantum_bytes=1024, queue_depth=10_000)
    lock = threading.Lock()
    inflight = {"now": 0, "peak": 0}

    def worker():
        for _ in range(25):
            gate.acquire("t", 512)
            with lock:
                inflight["now"] += 512
                inflight["peak"] = max(inflight["peak"], inflight["now"])
            time.sleep(0.0002)
            with lock:
                inflight["now"] -= 512
            gate.release(512)

    threads = [threading.Thread(target=worker) for _ in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # the gate's own ledger AND the independent measurement both respect
    # the cap; the storm (16 threads x 512B vs a 4KB budget) had to queue
    assert 0 < inflight["peak"] <= cap
    st = gate.stats()
    assert 0 < st["peak_inflight_bytes"] <= cap
    assert st["admitted"] == 16 * 25
    assert st["waits"] > 0
    assert st["inflight_bytes"] == 0 and st["queued"] == 0


def test_gate_sheds_oversize_and_full_queue():
    gate = AdmissionGate(1000, quantum_bytes=100, queue_depth=2)
    with pytest.raises(AdmissionShed):
        gate.acquire("big", 1001)           # can never fit: shed, not queued
    gate.acquire("a", 1000)                 # saturate the budget
    t1 = gate.submit("b", 100)
    t2 = gate.submit("c", 100)
    assert not t1.admitted and not t2.admitted
    with pytest.raises(AdmissionShed):      # queue_depth=2 exhausted
        gate.submit("d", 100)
    assert gate.stats()["shed"] == 2
    gate.release(1000)
    assert t1.admitted and t2.admitted


def test_gate_acquire_timeout_counts_as_shed():
    gate = AdmissionGate(100, quantum_bytes=100, queue_depth=10)
    gate.acquire("a", 100)
    with pytest.raises(AdmissionShed):
        gate.acquire("b", 100, timeout=0.01)
    st = gate.stats()
    assert st["shed"] == 1 and st["queued"] == 0
    gate.release(100)                       # the timed-out ticket is gone
    assert gate.stats()["inflight_bytes"] == 0


def test_gate_drr_interleaves_backlogged_head_with_tail():
    # a head tenant with a 6-deep backlog must NOT drain before the tail
    # tenant's single queued request: deficit round-robin admits one per
    # tenant per budget grant
    gate = AdmissionGate(300, quantum_bytes=100, queue_depth=100)
    gate.acquire("seed", 300)               # saturate so everything queues
    head = [gate.submit("head", 100) for _ in range(6)]
    tail = [gate.submit("tail", 100) for _ in range(2)]
    gate.release(100)                       # one slot: head's turn
    assert head[0].admitted and not tail[0].admitted
    gate.release(100)                       # next slot: TAIL's turn, not
    assert tail[0].admitted                 # head's 5-deep backlog
    assert not head[1].admitted
    gate.release(100)
    assert head[1].admitted
    gate.release(300)                       # free the three admitted above
    gate.release(300)                       # ...and drain the rest
    assert all(t.admitted for t in head + tail)


def test_gate_uncapped_tracks_but_never_blocks():
    gate = AdmissionGate(None)
    for _ in range(5):
        gate.acquire("t", 1 << 30)
    st = gate.stats()
    assert st["waits"] == 0 and st["admitted"] == 5
    assert st["peak_inflight_bytes"] == 5 * (1 << 30)


# ---- popularity -------------------------------------------------------------

def test_shard_popularity_hot_ordering():
    pop = ShardPopularity()
    for _ in range(5):
        pop.note(3)
    for _ in range(2):
        pop.note(1)
    pop.note(7)
    assert pop.hot(min_reads=2) == [3, 1]
    assert pop.hot(min_reads=6) == []
    assert pop.count(3) == 5 and pop.total == 8
    with pytest.raises(ValueError):
        pop.hot(min_reads=0)


# ---- serve group ------------------------------------------------------------

def test_serve_group_payload_identity_and_attribution():
    spec = ClusterSpec(num_nodes=4, max_inflight_bytes=1 << 20)
    c, payload = _packed_cluster(spec)
    with c:
        group = ServeGroup(c, num_tenants=6)
        for tenant in group.tenants:
            out = group.read_many(tenant, ["serve/f000.bin",
                                           "serve/f033.bin"])
            assert out == [payload, payload]
        assert group.attribution_ok()
        stats = group.stats()
        # 6 tenants x 2 files x 2048B, attributed per tenant, summing to
        # the serve-app lane totals exactly
        assert stats["serve_app_bytes"] == 6 * 2 * 2048
        assert sum(stats["tenant_bytes"].values()) == 6 * 2 * 2048
        assert set(stats["tenant_bytes"]) == set(group.tenants)
        assert stats["peak_inflight_bytes"] == 2 * 2048


def test_serve_app_lane_is_concurrent_not_consume():
    spec = ClusterSpec(num_nodes=2)
    c, _ = _packed_cluster(spec, num_files=8, per_part=4)
    with c:
        group = ServeGroup(c, num_tenants=2)
        c.reset_clocks()
        group.read_many("tenant-0000", [f"serve/f{i:03d}.bin"
                                       for i in range(8)])
        clock = c.clocks[0]
        # serving cost landed on the serve_app lane, NOT the trainer's
        # demand lane — and busy_s takes the max across concurrent lanes
        assert clock.serve_app_s > 0
        assert clock.consume_s == 0
        assert clock.busy_s == pytest.approx(
            max(clock.serve_app_s, clock.serve_s, clock.prefetch_s,
                clock.write_s))


def test_hot_shard_promotion_spreads_replicas():
    spec = ClusterSpec(num_nodes=4, selector="power-of-two",
                       max_inflight_bytes=1 << 20,
                       hot_shard_threshold=6, hot_shard_replication=3)
    c, _ = _packed_cluster(spec)
    with c:
        group = ServeGroup(c, num_tenants=8)
        # a head-concentrated trace: every tenant hammers partition 0
        for tenant in group.tenants:
            group.read_many(tenant, ["serve/f000.bin", "serve/f001.bin"])
        assert 0 in group.promoted
        holders = [n for n in c.live_nodes()
                   if 0 in c.nodes[n].partition_ids]
        assert len(holders) == 3
        # the routing layer sees the promotion: replica sets grew too
        _, loc = c.metadata.lookup("serve/f000.bin")
        assert len(set(loc.all_owners)) == 3
        # the cold tail was NOT promoted
        assert c.accounting is not None
        for pid in range(1, 8):
            assert pid not in group.promoted


def test_hot_output_promotion_uses_replicate_output():
    spec = ClusterSpec(num_nodes=4, max_inflight_bytes=1 << 20,
                       hot_shard_threshold=3, hot_shard_replication=2)
    c, _ = _packed_cluster(spec)
    with c:
        sess = c.connect(0, 0)
        sess.write_many([("out/hot.bin", b"H" * 512),
                         ("out/cold.bin", b"C" * 512)])
        group = ServeGroup(c, num_tenants=4)
        for tenant in group.tenants:
            assert group.read_many(tenant, ["out/hot.bin"]) == [b"H" * 512]
        assert "out/hot.bin" in group.promoted_outputs
        _, loc = c.output_ns.lookup("out/hot.bin")
        assert len(set(loc.all_owners)) == 2
        for o in loc.all_owners:
            assert c.nodes[o].has_output("out/hot.bin")
        _, cold = c.output_ns.lookup("out/cold.bin")
        assert len(set(cold.all_owners)) == 1


def test_serve_group_storm_respects_cluster_cap():
    cap = 8192
    spec = ClusterSpec(num_nodes=4, max_inflight_bytes=cap,
                       serve_quantum_bytes=4096)
    c, payload = _packed_cluster(spec)
    with c:
        group = ServeGroup(c, num_tenants=16)
        errors = []

        def drive(tenant):
            try:
                for r in range(8):
                    i = (hash((tenant, r)) % 64)
                    out = group.read_many(tenant, [f"serve/f{i:03d}.bin"])
                    assert out == [payload]
            except BaseException as exc:
                errors.append(exc)

        threads = [threading.Thread(target=drive, args=(t,))
                   for t in group.tenants]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert 0 < group.peak_inflight_bytes() <= cap
        assert group.attribution_ok()
        stats = group.stats()
        assert stats["shed"] == 0
        assert stats["serve_app_requests"] == 16 * 8


def test_tenant_session_delegates_namespace_and_restores_checkpoints():
    from repro.train.checkpoint import restore_from_session, save_to_session
    spec = ClusterSpec(num_nodes=4, max_inflight_bytes=1 << 22)
    c, _ = _packed_cluster(spec)
    with c:
        writer = c.connect(0, 0)
        state = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
        save_to_session(writer, 3, state)
        group = ServeGroup(c, num_tenants=2)
        ts = group.session("tenant-0001")
        assert isinstance(ts, TenantSession)
        # non-read verbs delegate to the raw session untouched
        assert ts.exists("ckpt/step_00000003/manifest.json")
        assert "step_00000003" in ts.listdir("ckpt")
        # restore streams through the GATED serve_app read path
        target = {"w": np.zeros((2, 3), dtype=np.float32)}
        restored, manifest = restore_from_session(ts, target)
        assert manifest["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
        assert c.accounting.tenant_bytes().get("tenant-0001", 0) > 0
        assert group.attribution_ok()


def test_serve_group_async_submit():
    spec = ClusterSpec(num_nodes=2, max_inflight_bytes=1 << 20)
    c, payload = _packed_cluster(spec, num_files=8, per_part=4)
    with c:
        group = ServeGroup(c, num_tenants=2)
        futs = [group.submit(t, ["serve/f002.bin"]) for t in group.tenants]
        for f in futs:
            assert f.result(timeout=30) == [payload]
        assert group.attribution_ok()


def test_serve_group_rejects_bad_shapes():
    spec = ClusterSpec(num_nodes=2)
    c, _ = _packed_cluster(spec, num_files=8, per_part=4)
    with c:
        with pytest.raises(ValueError):
            ServeGroup(c, num_tenants=0)
        with pytest.raises(ValueError):
            ServeGroup(c, num_tenants=2, hot_shard_threshold=1,
                       hot_shard_replication=5)
        group = ServeGroup(c, num_tenants=1)
        with pytest.raises(KeyError):
            group.read_many("tenant-9999", ["serve/f000.bin"])
