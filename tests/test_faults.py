"""Fault tolerance: deterministic injection, replica failover, churn.

The contract under test, end to end:

* a ``FaultPolicy`` on the spec drives a seeded :class:`FaultInjector`
  at the transport seam — the same policy produces the same fault
  sequence on every run;
* at R>=2 a mid-epoch node kill is INVISIBLE to readers: every
  ``read_many`` returns byte-identical data via replica failover, the
  retry ledger equals the injected-fault count exactly, and the dead
  node is detected organically (strike counter -> ``mark_failed``);
* at R=1 the same kill fails FAST and CLASSIFIED: ``NodeLostError``
  naming the lost partitions, never a hang;
* membership churn (``mark_failed`` / ``mark_joined`` / ``heal``)
  restores replication through the write path so reads survive a
  SECOND failure;
* the socket backend's dial path retries refused connections with
  backoff, its teardown names threads that fail to join, and
  ``drop_node`` closes a dead peer's serving loop and stripes.
"""
import socket as socket_mod
import threading

import pytest

from repro.fanstore.api import FanStoreSession
from repro.fanstore.backends.socket import _NodeServer, SocketBackend
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.faults import (FaultInjector, InjectedError,
                                   InjectedFault, NodeLostError,
                                   is_transport_failure)
from repro.fanstore.prefetch import EpochSchedule, SchedulerGroup
from repro.fanstore.prepare import prepare_dataset
from repro.fanstore.spec import ClusterSpec, FaultPolicy
from repro.fanstore import wire


def make_files(n=48):
    return {f"train/f_{i:03d}.bin":
            bytes((i * j * 2654435761) % 256 for j in range(600 + i))
            for i in range(n)}


def build(*, nodes=8, replication=2, faults=None, backend="modeled",
          placement="ring", files=None, partitions=16, **spec_kw):
    files = files if files is not None else make_files()
    blobs, _ = prepare_dataset(files, partitions, compress=False)
    spec = ClusterSpec(num_nodes=nodes, replication=replication,
                       placement=placement, backend=backend,
                       faults=faults, **spec_kw)
    c = FanStoreCluster.from_spec(spec)
    c.load_partitions(blobs, by_placement=True)
    return c, files


def owners_of(c, path):
    _, loc = c.metadata.lookup(path)
    return list(loc.all_owners)


# ---------------------------------------------------------------------------
# FaultPolicy: validation, spec round trip
# ---------------------------------------------------------------------------

def test_policy_validates_fractions():
    with pytest.raises(ValueError, match="drop_fraction"):
        FaultPolicy(drop_fraction=1.5)
    with pytest.raises(ValueError, match="sum"):
        FaultPolicy(drop_fraction=0.6, error_fraction=0.6)
    with pytest.raises(ValueError, match="delay_s"):
        FaultPolicy(delay_s=-1.0)


def test_policy_kill_requires_trigger():
    with pytest.raises(ValueError, match="kill_node"):
        FaultPolicy(kill_node=3)
    # either trigger form is enough
    FaultPolicy(kill_node=3, kill_at_step=1)
    FaultPolicy(kill_node=3, kill_at_op=10)


def test_spec_rejects_unknown_fault_key_with_suggestion():
    with pytest.raises(ValueError, match="drop_fraction"):
        ClusterSpec(num_nodes=2, faults={"drop_fractoin": 0.1})


def test_spec_faults_json_round_trip():
    spec = ClusterSpec(num_nodes=4, replication=2,
                       faults={"drop_fraction": 0.25, "seed": 9},
                       fault_threshold=5, retry_backoff_s=1e-3)
    back = ClusterSpec.from_json(spec.to_json())
    assert back == spec
    pol = back.make_fault_policy()
    assert isinstance(pol, FaultPolicy)
    assert pol.drop_fraction == 0.25 and pol.seed == 9
    assert ClusterSpec(num_nodes=2).make_fault_policy() is None


def test_spec_validates_retry_knobs():
    with pytest.raises(ValueError, match="fault_threshold"):
        ClusterSpec(num_nodes=2, fault_threshold=0)
    with pytest.raises(ValueError, match="retry_backoff"):
        ClusterSpec(num_nodes=2, retry_backoff_s=-1.0)


# ---------------------------------------------------------------------------
# FaultInjector: determinism, scoping
# ---------------------------------------------------------------------------

def _sequence(policy, ops=200):
    inj = FaultInjector(policy)
    out = []
    for _ in range(ops):
        try:
            inj.check(0, 1, "fetch")
            out.append("ok")
        except InjectedFault:
            out.append("drop")
        except InjectedError:
            out.append("err")
    return out, inj.stats()


def test_injector_deterministic_for_seed():
    pol = FaultPolicy(drop_fraction=0.2, error_fraction=0.1, seed=42)
    a, stats_a = _sequence(pol)
    b, stats_b = _sequence(pol)
    assert a == b
    assert stats_a == stats_b
    assert stats_a["dropped"] > 0 and stats_a["errored"] > 0
    assert stats_a["injected"] == stats_a["dropped"] + stats_a["errored"]
    c, _ = _sequence(FaultPolicy(drop_fraction=0.2, error_fraction=0.1,
                                 seed=43))
    assert c != a


def test_injector_scopes_owners_and_exempts_put_by_default():
    inj = FaultInjector(FaultPolicy(drop_fraction=1.0, owners=(2,)))
    inj.check(0, 1, "fetch")                       # other owner: clean
    with pytest.raises(InjectedFault):
        inj.check(0, 2, "fetch")
    inj.check(0, 2, "put")                         # puts exempt by default
    put_inj = FaultInjector(FaultPolicy(drop_fraction=1.0, verbs=("put",)))
    with pytest.raises(InjectedFault):
        put_inj.check(0, 2, "put")
    put_inj.check(0, 2, "fetch")                   # ...and nothing else


def test_injector_kill_fires_on_step_and_is_permanent():
    inj = FaultInjector(FaultPolicy(kill_node=1, kill_at_step=2))
    inj.check(0, 1, "fetch")                       # before the kill: clean
    inj.on_step(2)
    for _ in range(3):                             # after: every op fails
        with pytest.raises(InjectedFault):
            inj.check(0, 1, "fetch")
    inj.check(0, 3, "fetch")                       # other owners untouched
    assert inj.stats()["killed"] is True


def test_classifier():
    assert is_transport_failure(InjectedFault("x"))
    assert is_transport_failure(InjectedError("x"))
    assert is_transport_failure(ConnectionResetError("x"))
    assert is_transport_failure(TimeoutError("x"))
    assert is_transport_failure(wire.WireError("x"))
    assert not is_transport_failure(FileNotFoundError("x"))
    assert not is_transport_failure(NodeLostError("x"))
    # ERR frames can reconstruct the loss class across the wire
    assert wire._EXC_TYPES["NodeLostError"] is NodeLostError


# ---------------------------------------------------------------------------
# replication >= 2 placement (load_partitions + replica_set)
# ---------------------------------------------------------------------------

def test_load_partitions_by_placement_replica_sets():
    c, files = build(nodes=6, replication=3)
    try:
        for path in files:
            owners = owners_of(c, path)
            assert len(owners) == len(set(owners)) == 3
            _, loc = c.metadata.lookup(path)
            # the head of the replica set is the placement's primary
            assert owners[0] == loc.node_id
            assert loc.node_id == c.placement.replica_set(
                f"partition:{loc.partition_id:08d}", 3)[0]
            # every replica owner physically holds the partition
            for o in owners:
                assert loc.partition_id in c.nodes[o].partition_ids
    finally:
        c.close()


def test_load_partitions_replication_exceeding_nodes_raises():
    c, files = build(nodes=4, replication=1)
    try:
        blobs, _ = prepare_dataset(make_files(8), 4, compress=False)
        with pytest.raises(ValueError, match="replication"):
            c.load_partitions(blobs, replication=5)
    finally:
        c.close()


def test_reads_byte_identical_from_every_replica():
    c, files = build(nodes=6, replication=2)
    try:
        paths = sorted(files)
        # force reads onto each replica in turn by failing the other one
        probe = paths[0]
        owners = owners_of(c, probe)
        reader = next(n for n in range(6) if n not in owners)
        for excluded in owners:
            for o in owners:
                c.mark_joined(o)
            c.mark_failed(excluded)
            c.clear_caches()
            assert c.read_many(reader, [probe]) == [files[probe]]
    finally:
        c.close()


# ---------------------------------------------------------------------------
# failover reads, modeled wire
# ---------------------------------------------------------------------------

def _drive_epoch(c, files, steps=6):
    """Read the whole namespace from every live node, step by step,
    driving the injector's step clock. Returns nothing; raises on any
    client-visible failure."""
    paths = sorted(files)
    per = max(1, len(paths) // steps)
    for step in range(steps):
        c.tick_step(step)
        batch = paths[step * per:(step + 1) * per] or paths[:per]
        for nid in range(c.num_nodes):
            if nid in c.failed:
                continue
            got = c.read_many(nid, batch)
            assert [bytes(d) for d in got] == [files[p] for p in batch]


def test_kill_node_r2_reads_all_succeed_ledger_exact():
    c, files = build(nodes=8, replication=2,
                     faults={"kill_node": 3, "kill_at_step": 2, "seed": 7})
    try:
        _drive_epoch(c, files)
        s = c.fault_stats()
        assert s["killed"] and s["injected"] > 0
        # one retry tick per injected fault — exactly, no slack
        assert s["retries"] == s["injected"]
        # the kill was detected organically via the strike counter
        assert 3 in c.failed and s["failed_nodes"] == [3]
    finally:
        c.close()


def test_kill_node_r1_raises_classified_loss():
    c, files = build(nodes=6, replication=1,
                     faults={"kill_node": 2, "kill_at_op": 1, "seed": 7})
    try:
        victim_paths = [p for p in sorted(files)
                        if owners_of(c, p) == [2]]
        assert victim_paths, "placement gave node 2 nothing to lose"
        with pytest.raises(NodeLostError) as ei:
            c.read_many(0, victim_paths[:2])
        assert ei.value.partitions
        assert str(ei.value.partitions[0]) in str(ei.value)
        assert ei.value.paths
        s = c.fault_stats()
        # convergence is deterministic: threshold strikes, one retry each
        assert s["retries"] == s["injected"] == c.fault_threshold
        assert 2 in c.failed
        # once the owner is marked failed the loss is immediate (no more
        # injector raises, no more retries — fail fast, not fail slowly)
        with pytest.raises(NodeLostError):
            c.read_many(0, victim_paths[:1])
        assert c.fault_stats()["retries"] == s["retries"]
    finally:
        c.close()


def test_transient_drops_retry_without_marking_failed():
    # a 15% drop rate is transient noise, not a dead node: every read
    # must succeed and no owner may cross the strike threshold
    c, files = build(nodes=4, replication=2, fault_threshold=10,
                     faults={"drop_fraction": 0.15, "seed": 3})
    try:
        _drive_epoch(c, files, steps=4)
        s = c.fault_stats()
        assert s["injected"] > 0
        assert s["retries"] == s["injected"]
        assert not c.failed
    finally:
        c.close()


def test_injected_error_frames_failover_like_drops():
    c, files = build(nodes=4, replication=2, fault_threshold=10,
                     faults={"error_fraction": 0.15, "seed": 5})
    try:
        _drive_epoch(c, files, steps=4)
        s = c.fault_stats()
        assert s["errored"] > 0 and s["dropped"] == 0
        assert s["retries"] == s["injected"] == s["errored"]
    finally:
        c.close()


def test_injected_delay_accrues_on_consume_lane():
    c, files = build(nodes=4, replication=2,
                     faults={"delay_fraction": 1.0, "delay_s": 1e-3,
                             "seed": 0})
    try:
        _drive_epoch(c, files, steps=2)
        s = c.fault_stats()
        assert s["delayed"] > 0 and s["injected"] == 0
        assert sum(cl.consume_s for cl in c.clocks.values()) >= \
            s["delayed"] * 1e-3
    finally:
        c.close()


def test_prefetch_window_survives_kill():
    c, files = build(nodes=4, replication=2, cache_bytes=1 << 22,
                     faults={"kill_node": 1, "kill_at_op": 1, "seed": 11})
    try:
        paths = sorted(files)
        staged = c.prefetch_window(0, paths)
        assert staged > 0
        got = c.read_many(0, paths)
        assert [bytes(d) for d in got] == [files[p] for p in paths]
        s = c.fault_stats()
        assert s["retries"] == s["injected"] > 0
    finally:
        c.close()


def test_fault_stats_via_session_and_zero_default():
    c, files = build(nodes=4, replication=2,
                     faults={"kill_node": 1, "kill_at_op": 1, "seed": 1})
    try:
        sess = c.connect(0)
        _drive_epoch(c, files, steps=2)
        s = sess.fault_stats()
        assert s["injected"] > 0 and s["retries"] == s["injected"]
    finally:
        c.close()
    clean, _ = build(nodes=2, replication=1)
    try:
        s = clean.fault_stats()
        assert s["injected"] == s["retries"] == 0
        assert s["failed_nodes"] == []
    finally:
        clean.close()


# ---------------------------------------------------------------------------
# membership churn: mark_failed / mark_joined / heal
# ---------------------------------------------------------------------------

def test_heal_restores_replication_and_survives_second_failure():
    c, files = build(nodes=6, replication=2)
    try:
        c.mark_failed(0)
        copies = c.heal()
        assert copies > 0
        # every partition is back at R=2 on LIVE nodes
        for path in files:
            live = [o for o in owners_of(c, path) if o not in c.failed]
            assert len(set(live)) >= 2
        # so a second, different failure still leaves a live replica
        c.mark_failed(1)
        paths = sorted(files)
        got = c.read_many(2, paths)
        assert [bytes(d) for d in got] == [files[p] for p in paths]
        assert not c.unreachable_paths()
    finally:
        c.close()


def test_heal_async_runs_on_transport_pool():
    c, files = build(nodes=6, replication=2)
    try:
        c.mark_failed(0)
        assert c.heal_async().result() > 0
    finally:
        c.close()


def test_mark_joined_new_node_gets_ring_seat_and_heal_targets_it():
    c, files = build(nodes=4, replication=2)
    try:
        new_id = 4
        c.mark_joined(new_id)
        assert new_id in c.nodes and new_id in c.live_nodes()
        assert not c.nodes[new_id].partition_ids
        # the new seat participates in repair placement: fail a node and
        # heal — some copies may land on the new member, and either way
        # reads keep working with it in the membership
        c.mark_failed(1)
        assert c.heal() > 0
        paths = sorted(files)
        got = c.read_many(new_id, paths)
        assert [bytes(d) for d in got] == [files[p] for p in paths]
    finally:
        c.close()


def test_mark_failed_idempotent_and_rejoin_clears_strikes():
    c, files = build(nodes=4, replication=2)
    try:
        c.mark_failed(1)
        c.mark_failed(1)               # idempotent
        assert c.failed == {1}
        c.mark_joined(1)
        assert not c.failed
        paths = sorted(files)
        got = c.read_many(1, paths)
        assert [bytes(d) for d in got] == [files[p] for p in paths]
    finally:
        c.close()


def test_replicate_partition_pays_wire_and_updates_metadata():
    c, files = build(nodes=4, replication=1)
    try:
        path = sorted(files)[0]
        _, loc = c.metadata.lookup(path)
        src = loc.node_id
        dst = next(n for n in range(4) if n != src)
        before = c.clocks[src].write_s
        shipped = c.replicate_partition(loc.partition_id, src, dst)
        assert shipped > 0
        assert c.clocks[src].write_s > before       # the copy cost wire time
        assert dst in owners_of(c, path)
        assert loc.partition_id in c.nodes[dst].partition_ids
        # same-node copy is a no-op
        assert c.replicate_partition(loc.partition_id, src, src) == 0
    finally:
        c.close()


def test_scheduler_group_drop_node_detaches_members():
    c, files = build(nodes=4, replication=2, cache_bytes=1 << 22)
    try:
        paths = sorted(files)
        sched = EpochSchedule.from_trace(
            {nid: [paths[:8], paths[8:16]] for nid in range(4)}, cluster=c)
        group = SchedulerGroup.for_schedule(c, sched)
        assert len(group) == 4
        group.drop_node(2)
        assert len(group) == 3
        assert all(s.node_id != 2 for s in group.schedulers)
        group.ensure(1)
        group.drain()
        group.close()
    finally:
        c.close()


# ---------------------------------------------------------------------------
# socket backend: real wire failover, dial retry, teardown
# ---------------------------------------------------------------------------

def test_socket_drop_node_then_reads_fail_over():
    c, files = build(nodes=4, replication=2, backend="socket")
    try:
        paths = sorted(files)
        c.read_many(0, paths[:4])                  # start the wire
        # kill node 1's serving loop out from under the cluster — the
        # routing layer has NOT been told; failover must discover it
        c.transport.drop_node(1)
        # every epoch pass succeeds via failover; each pass that routes a
        # group at the dead peer strikes it, and within fault_threshold
        # passes the cluster marks it failed organically
        for _ in range(c.fault_threshold + 2):
            got = c.read_many(0, paths)
            assert [bytes(d) for d in got] == [files[p] for p in paths]
            if 1 in c.failed:
                break
        assert 1 in c.failed
        assert c.accounting.retries() > 0
    finally:
        c.close()


def test_socket_ensure_node_reopens_peer():
    c, files = build(nodes=4, replication=2, backend="socket")
    try:
        paths = sorted(files)
        c.read_many(0, paths[:4])
        c.mark_failed(1)
        assert 1 not in c.transport._servers
        c.mark_joined(1)
        assert 1 in c.transport._servers
        got = c.read_many(1, paths)
        assert [bytes(d) for d in got] == [files[p] for p in paths]
    finally:
        c.close()


def test_socket_dial_retries_refused_connections(monkeypatch):
    c, files = build(nodes=2, replication=1, backend="socket")
    try:
        c.start()                                  # spin the serving loops
        real = socket_mod.create_connection
        calls = {"n": 0}

        def flaky(address, *a, **kw):
            calls["n"] += 1
            if calls["n"] <= 2:
                raise ConnectionRefusedError("injected refuse")
            return real(address, *a, **kw)

        monkeypatch.setattr(
            "repro.fanstore.backends.socket.socket.create_connection",
            flaky)
        sock = c.transport._connect(1)
        sock.close()
        assert calls["n"] == 3                     # 2 refusals + 1 success
    finally:
        c.close()


def test_socket_dial_gives_up_with_connection_error(monkeypatch):
    c, files = build(nodes=2, replication=1, backend="socket")
    try:
        c.start()

        def always_refused(address, *a, **kw):
            raise ConnectionRefusedError("injected refuse")

        monkeypatch.setattr(
            "repro.fanstore.backends.socket.socket.create_connection",
            always_refused)
        with pytest.raises(ConnectionError, match="attempts"):
            c.transport._connect(1)
        # teardown (and drop_node) dial the accept loop awake — restore
        # the real dial before touching any serving loop
        monkeypatch.undo()
        # a dead (dropped) peer fails fast with a NAMED error, no dialing
        c.transport.drop_node(1)
        with pytest.raises(ConnectionError, match="no serving loop"):
            c.transport._connect(1)
    finally:
        c.close()


class _StuckThread:
    """Stands in for a handler thread that never joins (no real thread is
    leaked into the conftest fixture's enumerate check)."""
    name = "fanstore-conn-stuck"

    @staticmethod
    def is_alive():
        return True

    @staticmethod
    def join(timeout=None):
        pass


def test_node_server_teardown_names_stuck_threads():
    from repro.fanstore.store import NodeStore
    srv = _NodeServer(0, NodeStore(0), "127.0.0.1", join_timeout_s=0.2)
    srv._threads.append(_StuckThread())
    with pytest.raises(RuntimeError, match="fanstore-conn-stuck"):
        srv.close()


def test_socket_backend_close_surfaces_stuck_teardown():
    c, files = build(nodes=2, replication=1, backend="socket")
    closed = False
    try:
        c.start()
        c.transport._servers[1]._threads.append(_StuckThread())
        with pytest.raises(RuntimeError, match="failed to join"):
            c.close()
        closed = True
    finally:
        if not closed:
            c.close()
