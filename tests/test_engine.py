"""Layered I/O engine: batched transport accounting, client LRU cache,
replica failover under read_many, async futures, FS commit regression."""
import pytest

from repro.data.pipeline import PrefetchLoader
from repro.data.sampler import GlobalUniformSampler
from repro.fanstore.cache import ByteLRUCache
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.fs import FanStoreFS
from repro.fanstore.prepare import prepare_dataset


def make_cluster(num_nodes, files, *, replication=1, partitions=4, **kw):
    blobs, _ = prepare_dataset(files, partitions, compress=False)
    cluster = FanStoreCluster(num_nodes, **kw)
    cluster.load_partitions(blobs, replication=replication)
    return cluster


# ---- batched transport accounting -----------------------------------------

def test_read_many_single_owner_single_latency():
    """A batch of K files from one owner accrues exactly one latency_s."""
    files = {f"d/f{i}.bin": b"z" * 1000 for i in range(16)}
    cluster = make_cluster(2, files, partitions=1)   # everything on node 0
    cluster.reset_clocks()
    out = cluster.read_many(1, sorted(files))
    assert out == [files[p] for p in sorted(files)]
    net = cluster.net
    stored = 16 * 1000
    expect = net.latency_s + stored / net.bandwidth_Bps
    assert abs(cluster.clocks[1].consume_s - expect) < 1e-12
    # the owner handles ONE request message, not 16
    expect_serve = (net.open_overhead_s + stored / net.disk_bw_Bps
                    + stored / net.bandwidth_Bps)
    assert abs(cluster.clocks[0].serve_s - expect_serve) < 1e-12


def test_read_many_perfile_matches_read():
    """batched=False accrues byte-for-byte what N seed-style read calls do."""
    files = {f"d/f{i}.bin": b"q" * 500 for i in range(12)}
    a = make_cluster(3, files)
    b = make_cluster(3, files)
    a.reset_clocks()
    b.reset_clocks()
    for p in sorted(files):
        a.read(2, p)
    b.read_many(2, sorted(files), batched=False)
    for nid in range(3):
        assert abs(a.clocks[nid].consume_s - b.clocks[nid].consume_s) < 1e-12
        assert abs(a.clocks[nid].serve_s - b.clocks[nid].serve_s) < 1e-12
        assert a.clocks[nid].bytes_in == b.clocks[nid].bytes_in


def test_read_many_batched_strictly_cheaper_than_perfile():
    files = {f"d/f{i}.bin": b"z" * 2048 for i in range(64)}
    a = make_cluster(8, files, partitions=8)
    b = make_cluster(8, files, partitions=8)
    a.reset_clocks(), b.reset_clocks()
    a.read_many(0, sorted(files), batched=True)
    b.read_many(0, sorted(files), batched=False)
    assert a.makespan_s() < b.makespan_s()


def test_read_many_preserves_order_and_mixed_sources():
    files = {f"d/f{i}.bin": bytes([i]) * 100 for i in range(20)}
    cluster = make_cluster(4, files, replication=2, partitions=8)
    cluster.write_file(0, "out/w.bin", b"W" * 64)
    paths = sorted(files) + ["out/w.bin"]
    out = cluster.read_many(1, paths)
    assert out[:-1] == [files[p] for p in sorted(files)]
    assert out[-1] == b"W" * 64


def test_io_scaling_benchmark_batched_makespan_win_at_8_nodes():
    """Acceptance pin: the --batched benchmark path reports strictly lower
    makespan than the per-file path at >= 8 nodes."""
    import pathlib
    import sys
    root = str(pathlib.Path(__file__).resolve().parents[1])
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.io_scaling import CPU_NET, run_one
    kw = dict(nodes=8, file_size=8192, count=64, net=CPU_NET,
              reads_per_node=32)
    per_file = run_one(batched=False, **kw)
    batched = run_one(batched=True, **kw)
    assert batched["makespan_s"] < per_file["makespan_s"]


# ---- failover + replica selection under read_many --------------------------

def test_read_many_failover_with_replication():
    files = {f"d/f{i}.bin": bytes([i % 250]) * 300 for i in range(40)}
    cluster = make_cluster(4, files, replication=2, partitions=8)
    cluster.fail_node(2)
    out = cluster.read_many(0, sorted(files))
    assert out == [files[p] for p in sorted(files)]
    assert cluster.clocks[2].serve_s == 0.0          # failed node never serves
    with pytest.raises(IOError):
        cluster.read_many(2, sorted(files)[:1])      # failed requester


def test_read_many_least_loaded_spreads_across_replicas():
    files = {f"d/f{i}.bin": b"z" * 1000 for i in range(64)}
    cluster = make_cluster(4, files, replication=2, partitions=8)
    cluster.reset_clocks()
    cluster.read_many(3, sorted(files))              # node 3 reads everything
    # replica sets are {0,2} and {1,3}-style pairs; remote traffic must not
    # pile onto a single owner
    serving = [cluster.clocks[n].serve_s for n in range(3)]
    busy = [s for s in serving if s > 0]
    assert len(busy) >= 2
    assert max(busy) < 2.0 * min(busy) + 1e-9


def test_read_many_all_replicas_failed():
    files = {f"d/f{i}.bin": b"z" * 100 for i in range(8)}
    # 2 partitions round-robin onto nodes 0 and 1; node 2 owns nothing
    cluster = make_cluster(3, files, replication=1, partitions=2)
    cluster.fail_node(0)
    cluster.fail_node(1)
    with pytest.raises(IOError):
        cluster.read_many(2, sorted(files))


# ---- client-side LRU cache --------------------------------------------------

def test_lru_cache_hit_miss_eviction_accounting():
    cache = ByteLRUCache(250)
    assert cache.get("a") is None                    # miss on empty
    cache.put("a", b"x" * 100)
    cache.put("b", b"y" * 100)
    assert cache.get("a").data == b"x" * 100         # a is now MRU
    cache.put("c", b"z" * 100)                       # evicts b (LRU)
    assert cache.get("b") is None
    assert cache.get("a") is not None
    assert cache.stats.evictions == 1
    assert cache.stats.evicted_bytes == 100
    assert cache.used_bytes == 200
    assert 0 < cache.stats.hit_rate < 1
    # payloads over the whole budget are not cached
    assert cache.put("huge", b"h" * 1000) == 0
    assert "huge" not in cache


def test_cluster_cache_hits_on_second_epoch():
    files = {f"d/f{i}.bin": b"z" * 1000 for i in range(16)}
    cluster = make_cluster(2, files, partitions=1, cache_bytes=1 << 20)
    cluster.reset_clocks()
    first = cluster.read_many(1, sorted(files))
    second = cluster.read_many(1, sorted(files))
    assert first == second == [files[p] for p in sorted(files)]
    clock = cluster.clocks[1]
    assert clock.cache_misses == 16 and clock.cache_hits == 16
    assert clock.cache_hit_bytes == 16 * 1000
    assert cluster.cache_hit_rate() == 0.5
    # a cache hit must be modeled cheaper than the remote fetch it replaced
    hit_cost = cluster.net.cache_cost(1000)
    assert hit_cost < cluster.net.remote_cost(1000)


def test_cluster_cache_eviction_accounting_with_small_budget():
    files = {f"d/f{i}.bin": b"z" * 1000 for i in range(16)}
    cluster = make_cluster(2, files, partitions=1,
                           cache_bytes=3500)         # holds 3 files
    cluster.read_many(1, sorted(files))
    clock = cluster.clocks[1]
    assert clock.cache_evictions == 13               # 16 inserts, 3 resident
    assert cluster.caches[1].used_bytes <= 3500


def test_cache_size_only_entries_materialize_false():
    files = {f"d/f{i}.bin": b"z" * 1000 for i in range(4)}
    cluster = make_cluster(2, files, partitions=1, cache_bytes=1 << 20)
    cluster.read_many(1, sorted(files), materialize=False)
    cluster.read_many(1, sorted(files), materialize=False)
    assert cluster.clocks[1].cache_hits == 4         # placeholders hit
    # a materializing read must NOT serve payloads from size-only entries
    out = cluster.read_many(1, sorted(files))
    assert out == [files[p] for p in sorted(files)]


# ---- async future API -------------------------------------------------------

def test_read_many_async_returns_future():
    files = {f"d/f{i}.bin": bytes([i]) * 200 for i in range(10)}
    cluster = make_cluster(3, files)
    fut = cluster.read_many_async(0, sorted(files))
    assert fut.result(timeout=30) == [files[p] for p in sorted(files)]
    cluster.transport.shutdown()


def test_prefetch_loader_batched_path():
    files = {f"d/f{i:03d}.bin": bytes([i]) * 64 for i in range(32)}
    cluster = make_cluster(2, files)
    paths = sorted(files)
    sampler = GlobalUniformSampler(len(paths), 8, seed=0)
    loader = PrefetchLoader(
        sampler,
        fetch_many=lambda idxs: cluster.read_many(
            0, [paths[i] for i in idxs]),
        decode=lambda bl: bl)
    seen = []
    for batch in loader.batches(4):
        assert len(batch) == 8
        seen.extend(batch)
    assert all(isinstance(b, bytes) and len(b) == 64 for b in seen)
    with pytest.raises(ValueError):
        PrefetchLoader(sampler, decode=lambda b: b)  # no fetch at all


# ---- FS layer commit regression --------------------------------------------

def test_fs_double_create_raises_via_close():
    """Regression: FanStoreFile.close() used to bypass write_file's
    single-write check and the metadata-forward accounting."""
    files = {"d/in.bin": b"i" * 100}
    cluster = make_cluster(2, files, partitions=1)
    fs = FanStoreFS(cluster, node_id=0)
    with fs.open("/fanstore/out/gen.bin", "wb") as f:
        f.write(b"first")
    assert cluster.read(1, "out/gen.bin") == b"first"
    f2 = fs.open("/fanstore/out/gen.bin", "wb")
    f2.write(b"second")
    with pytest.raises(PermissionError):
        f2.close()
    # the losing writer must not have clobbered the committed payload
    assert cluster.read(1, "out/gen.bin") == b"first"


def test_fs_close_accounts_metadata_forward():
    files = {"d/in.bin": b"i" * 100}
    cluster = make_cluster(4, files, partitions=1)
    fs = FanStoreFS(cluster, node_id=1)
    cluster.reset_clocks()
    with fs.open("/fanstore/out/acct.bin", "wb") as f:
        f.write(b"x" * 512)
    # committing through the FS layer accrues the same modeled time as
    # cluster.write_file: payload flush + (possibly) a metadata forward
    assert cluster.clocks[1].consume_s > 0.0
    other = FanStoreCluster(4)
    other.load_partitions(
        prepare_dataset(files, 1, compress=False)[0], replication=1)
    other.reset_clocks()
    other.write_file(1, "out/acct.bin", b"x" * 512)
    assert abs(cluster.clocks[1].consume_s - other.clocks[1].consume_s) < 1e-12
