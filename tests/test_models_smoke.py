"""Per-arch smoke: reduced config, one forward/train step, shapes + no NaNs,
plus prefill/decode consistency against the teacher-forced forward."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import build_model
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_state, make_train_step


def _batch(cfg, rng, b=2, t=24, extra=0):
    if cfg.family == "audio":
        toks = rng.integers(0, cfg.vocab_size, (b, t + extra,
                                                cfg.num_codebooks))
    else:
        toks = rng.integers(0, cfg.vocab_size, (b, t + extra))
    out = {"tokens": jnp.asarray(toks.astype(np.int32))}
    if cfg.family == "vlm":
        out["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, cfg.d_model)),
            jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, rng, b=2, t=32)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    # one optimizer step moves the loss
    ocfg = OptimizerConfig(lr=5e-3, warmup_steps=1, total_steps=10)
    state = init_state(model, jax.random.key(0), ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    state, m0 = step(state, batch)
    state, m1 = step(state, batch)
    assert np.isfinite(float(m1["loss"]))
    assert float(m1["loss"]) < float(m0["loss"])
    assert np.isfinite(float(m1["grad_norm"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch, rng):
    cfg = get_smoke(arch).scaled(remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    b, t, extra = 2, 20, 3
    batch_all = _batch(cfg, rng, b, t, extra)
    batch_pre = dict(batch_all)
    batch_pre["tokens"] = batch_all["tokens"][:, :t]
    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    max_len = prefix + t + 8
    logits, caches = jax.jit(
        lambda p, bb: model.prefill(p, bb, max_len))(params, batch_pre)
    full = jax.jit(model.logits_full)(params, batch_all)
    scale = float(jnp.max(jnp.abs(full.astype(jnp.float32)))) + 1e-6
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(full[:, t - 1], np.float32),
        atol=0.1 * scale, rtol=0.1)
    dec = jax.jit(model.decode_step)
    for s in range(extra):
        nt = batch_all["tokens"][:, t + s: t + s + 1]
        lg, caches = dec(params, nt, caches, jnp.int32(prefix + t + s))
        np.testing.assert_allclose(
            np.asarray(lg, np.float32),
            np.asarray(full[:, t + s], np.float32),
            atol=0.1 * scale, rtol=0.2)


def test_full_configs_census():
    """Full (published) configs build segment plans and count params sanely
    via eval_shape (no allocation)."""
    expected_params = {          # rough published totals, +-20%
        "falcon-mamba-7b": 7.3e9,
        "deepseek-v2-236b": 236e9,
        "qwen2-72b": 72e9,
        "qwen1.5-32b": 32e9,
        "nemotron-4-15b": 15e9,
        "chatglm3-6b": 6.2e9,
        "musicgen-large": 3.3e9,
        "internvl2-76b": 70e9,     # LM backbone only (ViT is a stub)
        "granite-moe-3b-a800m": 3.4e9,
        "hymba-1.5b": 1.5e9,
    }
    for arch, want in expected_params.items():
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.key(0))
        n = model.param_count(shapes)
        assert 0.55 * want < n < 1.6 * want, (arch, n, want)


def test_moe_active_params_smaller():
    cfg = get_config("deepseek-v2-236b")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    total = model.param_count(shapes)
    active = model.active_param_count(shapes)
    assert active < 0.25 * total         # 21B active / 236B total
