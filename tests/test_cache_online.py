"""Online cache intelligence: LFU / ARC / GDSF / Predictive policy
behavior, invalidate/clear correctness across every policy, cross-epoch
prefetch stitching, and per-job cache attribution tie-out."""
import threading

import numpy as np
import pytest

from repro.data.sampler import GlobalUniformSampler
from repro.fanstore.cache import (ArcCache, ByteLRUCache, GdsfCache,
                                  LFUCache, PredictiveCache, TwoQCache,
                                  make_cache)
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.prefetch import EpochSchedule, PrefetchScheduler
from repro.fanstore.prepare import prepare_dataset
from repro.fanstore.spec import ClusterSpec


ONLINE_POLICIES = ["lru", "2q", "lfu", "arc", "gdsf", "predictive"]


def simulate(cache, trace, size=100):
    """Demand-read loop as the cluster drives it: get, then put on miss."""
    for p in trace:
        if cache.get(p) is None:
            cache.put(p, b"x" * size)
    return cache.stats


def permutation_trace(num_files, epochs, seed=0):
    """Per-epoch full permutations — the paper's global-shuffle access."""
    rng = np.random.default_rng(seed)
    paths = [f"f{i}" for i in range(num_files)]
    out = []
    for _ in range(epochs):
        out.extend(paths[int(i)] for i in rng.permutation(num_files))
    return out


# ---- registry / spec plumbing ----------------------------------------------

def test_make_cache_knows_every_online_policy():
    for name, cls in (("lfu", LFUCache), ("arc", ArcCache),
                      ("gdsf", GdsfCache), ("predictive", PredictiveCache)):
        assert isinstance(make_cache(name, 1000), cls)
    with pytest.raises(ValueError, match="unknown cache policy"):
        make_cache("arcc", 1000)
    with pytest.raises(ValueError, match="did you mean 'arc'"):
        ClusterSpec(num_nodes=2, cache_bytes=1000, cache_policy="arcc")


def test_policy_options_flow_from_spec_to_member_caches():
    spec = ClusterSpec(num_nodes=2, cache_bytes=1000, cache_policy="lfu",
                       cache_policy_options={"aging_interval": 7})
    cluster = FanStoreCluster(spec=spec)
    assert all(c.aging_interval == 7 for c in cluster.caches.values())
    with pytest.raises(ValueError, match="cache_policy_options"):
        ClusterSpec(num_nodes=2, cache_bytes=1000, cache_policy="lru",
                    cache_policy_options={"aging_interval": 7})


# ---- LFU --------------------------------------------------------------------

def test_lfu_evicts_least_frequent():
    cache = LFUCache(300)
    for p, hits in (("a", 3), ("b", 2), ("c", 0)):
        cache.get(p), cache.put(p, b"x" * 100)
        for _ in range(hits):
            assert cache.get(p) is not None
    cache.get("d"), cache.put("d", b"x" * 100)     # evicts c (freq 1)
    assert "c" not in cache and "a" in cache and "b" in cache


def test_lfu_aging_halves_stale_credit():
    cache = LFUCache(200, aging_interval=4)
    cache.get("a"), cache.put("a", b"x" * 100)
    for _ in range(6):                             # a earns credit, then ages
        cache.get("a")
    assert cache._freq["a"] < 7                    # halved at least once
    cache.get("b"), cache.put("b", b"x" * 100)
    for _ in range(3):
        cache.get("b")
    # fresh credit now outranks the aged hot streak's remainder
    cache.get("c"), cache.put("c", b"x" * 100)
    assert "b" in cache


# ---- ARC --------------------------------------------------------------------

def test_arc_ghost_hit_promotes_to_t2_and_grows_p():
    cache = ArcCache(200)
    cache.get("a"), cache.put("a", b"x" * 100)
    cache.get("b"), cache.put("b", b"x" * 100)
    cache.get("c"), cache.put("c", b"x" * 100)     # evicts a -> B1 ghost
    assert "a" in cache._b1 and cache._p == 0.0
    assert cache.get("a") is None                  # ghost hit: miss, refetch
    cache.put("a", b"x" * 100)
    assert "a" in cache._t2 and "a" not in cache._b1
    assert cache._p > 0.0                          # recency deserved more


def test_arc_second_touch_promotes_within_residency():
    cache = ArcCache(300)
    cache.get("a"), cache.put("a", b"x" * 100)
    assert "a" in cache._t1
    assert cache.get("a") is not None
    assert "a" in cache._t2 and "a" not in cache._t1


# ---- GDSF -------------------------------------------------------------------

def test_gdsf_keeps_small_hot_over_large_cold():
    cache = GdsfCache(1000, cost_bytes=100.0)
    cache.get("small"), cache.put("small", b"x" * 100)
    assert cache.get("small") is not None          # freq 2
    cache.get("big"), cache.put("big", b"x" * 800)
    cache.get("more"), cache.put("more", b"x" * 400)   # must evict big
    assert "big" not in cache and "small" in cache


def test_gdsf_inflation_rises_on_eviction_not_invalidate():
    cache = GdsfCache(200)
    cache.get("a"), cache.put("a", b"x" * 100)
    cache.get("b"), cache.put("b", b"x" * 100)
    cache.get("c"), cache.put("c", b"x" * 100)     # eviction -> L inflates
    assert cache._L > 0.0
    before = cache._L
    cache.invalidate("b")                          # unlink, NOT an eviction
    assert cache._L == before


# ---- Predictive -------------------------------------------------------------

def test_predictive_learns_period_and_evicts_farthest():
    cache = PredictiveCache(200)
    # a returns every 2 accesses; b every 8 — teach both periods
    trace = ["a", "b"] + ["a", "x1", "a", "x2", "a", "b"] * 3
    simulate(cache, trace)
    assert cache._ewma["a"] < cache._ewma["b"]
    # with a and b resident, the next eviction removes the farthest
    # predicted reuse — which must not be the short-period a
    cache.clear()
    simulate(cache, trace)
    cache.get("a"), cache.put("a", b"x" * 100)
    cache.get("b"), cache.put("b", b"x" * 100)
    cache.get("z"), cache.put("z", b"x" * 100)
    assert "a" in cache


def test_predictive_history_survives_eviction():
    cache = PredictiveCache(200)
    simulate(cache, ["a", "b", "a", "b"])          # residents a, b; period 2
    cache.get("c"), cache.get("c")                 # teach c period 1 (misses
    cache.put("c", b"x" * 100)                     # only), then insert: the
    assert "a" not in cache                        # overdue a is farthest
    assert cache._ewma["a"] == 2.0                 # period knowledge kept


def test_predictive_beats_lru_on_epoch_permutations():
    """The paper's global-shuffle trace: recency is anti-predictive (the
    file just read is a full epoch from reuse), learned periods are not."""
    trace = permutation_trace(32, 6, seed=0)
    lru = simulate(ByteLRUCache(16 * 100), trace)
    pred = simulate(PredictiveCache(16 * 100), trace)
    assert pred.hit_rate > lru.hit_rate


# ---- invalidate / clear across every policy ---------------------------------

def _mentions(cache, path):
    """Does any policy-side structure still know this path?"""
    for attr in ("_freq", "_H", "_last", "_ewma", "_t1", "_t2", "_b1",
                 "_b2", "_a1in", "_ghost", "_future"):
        d = getattr(cache, attr, None)
        if d is not None and path in d:
            return True
    return path in cache


@pytest.mark.parametrize("policy", ONLINE_POLICIES)
def test_invalidate_forgets_path_everywhere(policy):
    cache = make_cache(policy, 300)
    simulate(cache, ["a", "b", "c", "a", "b", "d", "a"])   # force evictions
    for p in ("a", "b", "c", "d"):
        cache.invalidate(p)
        assert not _mentions(cache, p), (policy, p)
    assert cache.used_bytes == sum(e.size for e in cache._entries.values())


def test_arc_invalidated_path_is_not_a_ghost_hit():
    cache = ArcCache(200)
    cache.get("a"), cache.put("a", b"x" * 100)
    cache.get("b"), cache.put("b", b"x" * 100)
    cache.get("c"), cache.put("c", b"x" * 100)     # a -> B1 ghost
    cache.invalidate("a")                          # deleted file: no ghost
    p = cache._p
    cache.get("a"), cache.put("a", b"x" * 100)     # rewrite = brand new
    assert "a" in cache._t1 and cache._p == p


@pytest.mark.parametrize("policy", ONLINE_POLICIES)
def test_clear_is_indistinguishable_from_fresh(policy):
    trace = permutation_trace(12, 3, seed=1)
    cache = make_cache(policy, 500)
    simulate(cache, trace)
    cache.clear()
    assert cache.used_bytes == 0
    before = cache.stats.hits
    simulate(cache, trace)
    fresh = simulate(make_cache(policy, 500), trace)
    assert cache.stats.hits - before == fresh.hits, policy


# ---- cross-epoch stitching --------------------------------------------------

def test_from_sampler_stitches_consecutive_epochs():
    paths = [f"d/f{i}.bin" for i in range(16)]
    sampler = GlobalUniformSampler(16, 8, seed=0)
    one = EpochSchedule.from_sampler(sampler, paths, num_requesters=2)
    two = EpochSchedule.from_sampler(sampler, paths, num_requesters=2,
                                     epochs=2)
    assert one.epochs == 1 and two.epochs == 2
    assert two.steps_per_epoch == one.num_steps
    assert two.num_steps == 2 * one.num_steps
    # epoch 0 of the stitched horizon IS the single-epoch schedule, and
    # epoch 1 is numbered right after it (global steps, no reset)
    r0 = two.for_requester(0)
    spe = one.steps_per_epoch
    assert [s for s in r0 if s.step < spe] == one.for_requester(0)
    assert {s.step for s in r0} == set(range(two.num_steps))
    # a different permutation per epoch, same multiset of files across
    # the requesters together (each epoch covers the dataset once)
    both = two.for_requester(0) + two.for_requester(1)
    e0 = sorted(s.path for s in both if s.step < spe)
    e1 = sorted(s.path for s in both if s.step >= spe)
    assert e0 == e1 == sorted(paths)


def test_boundary_window_covers_step_zero_of_next_epoch():
    """window=2 over two stitched 3-step epochs: the window starting at
    global step 2 spans the boundary — epoch 0's last step AND epoch 1's
    step 0 ride one prefetch round trip, no drain-and-refill."""
    files = {f"d/f{i}.bin": b"z" * 256 for i in range(12)}
    blobs, _ = prepare_dataset(files, 4, compress=False)
    cluster = FanStoreCluster(2, cache_bytes=12 * 512, cache_policy="belady")
    cluster.load_partitions(blobs)
    paths = sorted(files)
    rng = np.random.default_rng(0)
    epoch_steps = []
    for _ in range(2):
        perm = [paths[int(i)] for i in rng.permutation(12)]
        epoch_steps.append([perm[s * 4:(s + 1) * 4] for s in range(3)])
    flat = [b for ep in epoch_steps for b in ep]
    sched = EpochSchedule.from_trace({1: flat}, cluster)
    pf = PrefetchScheduler(cluster, sched, 1, window_steps=2)
    starts = [w[0] for w in pf._windows]
    assert starts == [0, 2, 4]                     # no per-epoch reset
    boundary = dict((w[0], w[1]) for w in pf._windows)[2]
    assert set(epoch_steps[1][0]) <= set(boundary)  # covers e+1 step 0
    for gstep, batch in enumerate(flat):
        pf.ensure(gstep + 2)
        pf.wait_ready(gstep)
        cluster.read_many(1, batch, materialize=False)
    pf.close()
    # every path fetched at most once per window it appears in — and with
    # the cache holding the dataset, prefetch never refetches: windows ==
    # ceil(6/2), each path staged exactly twice (once per epoch)
    assert pf.windows_issued == 3
    assert cluster.accounting.retries() == 0       # faults off: clean ledger
    assert cluster.caches[1].stats.hits == len(flat) * 4   # all demand hits
    cluster.close()


def test_tier_extend_future_feeds_belady_next_epoch():
    files = {f"d/f{i}.bin": b"z" * 256 for i in range(8)}
    blobs, _ = prepare_dataset(files, 4, compress=False)
    cluster = FanStoreCluster(2, cache_bytes=8 * 512, cache_policy="belady")
    cluster.load_partitions(blobs)
    paths = sorted(files)
    EpochSchedule.from_trace({1: [[p] for p in paths]}
                             ).install_futures(cluster)
    cluster.cache_tiers[1].extend_future(paths)    # next epoch, same order
    q = cluster.caches[1]._future[paths[0]]
    assert list(q) == [0, len(paths)]
    cluster.close()


# ---- per-job attribution ----------------------------------------------------

def _assert_job_tie_out(cluster, node):
    tier = cluster.cache_tiers[node]
    clock = cluster.clocks[node]
    total = tier.stats
    for field, clock_jobs, clock_total in (
            ("hits", clock.job_cache_hits, clock.cache_hits),
            ("misses", clock.job_cache_misses, clock.cache_misses),
            ("hit_bytes", clock.job_cache_hit_bytes, clock.cache_hit_bytes)):
        tier_sum = sum(getattr(st, field) for st in tier.job_stats.values())
        assert tier_sum == getattr(total, field), field
        assert sum(clock_jobs.values()) == clock_total == tier_sum, field


def test_two_jobs_share_tier_with_exact_attribution():
    files = {f"d/f{i}.bin": b"z" * 512 for i in range(16)}
    blobs, _ = prepare_dataset(files, 4, compress=False)
    spec = ClusterSpec(num_nodes=2, workers_per_node=2,
                       cache_bytes=16 * 1024)
    cluster = FanStoreCluster(spec=spec)
    cluster.load_partitions(blobs)
    paths = sorted(files)
    train = cluster.connect(1, 0, job="train")
    evalj = cluster.connect(1, 1, job="eval")
    train.read_many(paths)                         # cold: misses
    evalj.read_many(paths[:8])                     # warm via shared tier
    train.read_many(paths)
    tier = cluster.cache_tiers[1]
    assert set(tier.job_stats) == {"train", "eval"}
    assert tier.job_stats["eval"].hits == 8        # rode train's fetches
    assert tier.job_stats["train"].misses == len(paths)
    _assert_job_tie_out(cluster, 1)
    cluster.close()


def test_unnamed_job_books_onto_default_ledger():
    files = {"d/a.bin": b"z" * 128}
    blobs, _ = prepare_dataset(files, 1, compress=False)
    cluster = FanStoreCluster(2, cache_bytes=1024)
    cluster.load_partitions(blobs)
    cluster.read_many(1, ["d/a.bin"])
    tier = cluster.cache_tiers[1]
    assert set(tier.job_stats) == {tier.DEFAULT_JOB}
    _assert_job_tie_out(cluster, 1)
    cluster.close()


def test_job_attribution_survives_concurrent_thread_storm():
    files = {f"d/f{i}.bin": b"z" * 256 for i in range(32)}
    blobs, _ = prepare_dataset(files, 4, compress=False)
    spec = ClusterSpec(num_nodes=2, workers_per_node=2,
                       cache_bytes=16 * 256)
    cluster = FanStoreCluster(spec=spec)
    cluster.load_partitions(blobs)
    paths = sorted(files)
    sessions = [cluster.connect(1, 0, job="train"),
                cluster.connect(1, 1, job="eval")]
    rounds = 8

    def storm(sess, seed):
        rng = np.random.default_rng(seed)
        for _ in range(rounds):
            picks = [paths[int(i)] for i in rng.integers(0, 32, size=8)]
            sess.read_many(picks, materialize=False)

    threads = [threading.Thread(target=storm, args=(s, i))
               for i, s in enumerate(sessions)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tier = cluster.cache_tiers[1]
    for job in ("train", "eval"):
        st = tier.job_stats[job]
        assert st.hits + st.misses == rounds * 8, job
    _assert_job_tie_out(cluster, 1)
    cluster.close()
