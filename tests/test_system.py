"""End-to-end behaviour test for the paper's system: the full FanStore
story in one scenario — prepare, distribute, read through POSIX surface,
train, fail a node, keep training."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data.pipeline import PrefetchLoader
from repro.data.sampler import GlobalUniformSampler
from repro.data.synthetic import files_to_tokens, token_dataset, tokens_to_files
from repro.fanstore import FanStoreCluster, FanStoreFS, prepare_dataset
from repro.models import build_model
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_state, make_train_step


def test_fanstore_system_end_to_end():
    seq, vocab, n_files = 32, 128, 96
    tokens = token_dataset(n_files, seq, vocab, seed=7)
    files = tokens_to_files(tokens)
    blobs, report = prepare_dataset(files, 6, compress=True)
    assert report.num_files == n_files

    cluster = FanStoreCluster(3, codec="lzss")
    cluster.load_partitions(blobs, replication=2)
    fs = FanStoreFS(cluster, node_id=0)
    assert fs.walk_count("/fanstore") == n_files          # global namespace

    cfg = get_smoke("qwen2-72b").scaled(vocab_size=vocab)
    model = build_model(cfg)
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    state = init_state(model, jax.random.key(0), ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    paths = sorted(files)
    sampler = GlobalUniformSampler(n_files, 16, seed=0)
    def fetch(i):
        live = cluster.live_nodes()          # failed readers are rerouted
        return cluster.read(live[i % len(live)], paths[i])

    loader = PrefetchLoader(
        sampler, fetch=fetch,
        decode=lambda bl: {"tokens": jnp.asarray(files_to_tokens(bl, seq))},
        num_threads=4)
    losses = []
    for i, batch in enumerate(loader.batches(10)):
        if i == 5:
            cluster.fail_node(2)      # mid-training failure; replicas cover
            assert cluster.unreachable_paths() == []
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    # output write path: visible-on-close, single-write
    cluster.write_file(0, "out/final.ckpt", b"\x01" * 256)
    assert cluster.read(1, "out/final.ckpt") == b"\x01" * 256
